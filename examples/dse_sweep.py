"""Beyond-paper: design-space exploration of approximate multipliers inside
an LM — the paper's technique as a first-class model feature.

Trains a reduced qwen2 for a few steps under several (multiplier, VBL)
settings using the calibrated white-noise error model, reporting the loss
penalty next to the modeled multiplier power saving: the LM-scale version
of the paper's SNR-vs-power tradeoff.

    PYTHONPATH=src python examples/dse_sweep.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import AmmConfig, get_arch, reduced
from repro.core.hwmodel import power
from repro.core.multipliers import MulSpec
from repro.data.pipeline import DataConfig, global_batch
from repro.launch.mesh import make_host_mesh
from repro.models import ModelRuntime
from repro.train.optimizer import OptConfig
from repro.train.trainstep import TrainConfig, init_train_state, \
    make_train_step

STEPS = 8


def run(amm_mode, vbl):
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode=amm_mode, mul="bbm0", wl=16, param=vbl,
                           apply_to="mlp"))
    rt = ModelRuntime.build(cfg)
    mesh = make_host_mesh(1, 1)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=STEPS))
    step = make_train_step(cfg, rt, tc, mesh, global_batch=4)
    params, opt = init_train_state(cfg, tc, mesh, jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    loss = None
    for i in range(STEPS):
        t, l = global_batch(dc, i)
        params, opt, m = step(params, opt, jnp.asarray(t), jnp.asarray(l),
                              jax.random.fold_in(jax.random.key(1), i))
        loss = float(m["loss"])
    return loss


def main():
    base = run("off", 0)
    print(f"exact multipliers:        final loss {base:.4f}")
    p0 = power(MulSpec("bbm0", 16, 0))
    for vbl in (9, 13, 15):
        l = run("noise", vbl)
        saving = 100 * (1 - power(MulSpec("bbm0", 16, vbl)) / p0)
        print(f"bbm0 WL=16 VBL={vbl:2d}:      final loss {l:.4f} "
              f"(+{l - base:+.4f})   multiplier power -{saving:.1f}%")
    # the true datapath, not the noise proxy: since the exact-dot +
    # low-bit-correction lowering, mode="bitexact" runs as dense
    # contractions (O(B*N) live memory) and is affordable in the sweep —
    # the gap to the noise row above IS the noise model's error at LM scale
    l = run("bitexact", 13)
    print(f"bbm0 WL=16 VBL=13 (bit-exact datapath): final loss {l:.4f} "
          f"(+{l - base:+.4f})")


if __name__ == "__main__":
    main()
