"""End-to-end driver: train a ~small LM for a few hundred steps on the
synthetic pipeline with the fault-tolerant loop (deliverable b).

    PYTHONPATH=src python examples/train_lm.py --steps 200
(thin wrapper over repro.launch.train with curated defaults)
"""
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = ["--arch", "llama3.2-3b", "--reduced", "--steps", "200",
                "--batch", "8", "--seq", "128", "--ckpt-every", "50"]
    # user args win
    train_main(defaults + args)
