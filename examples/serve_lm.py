"""End-to-end driver: serve a reduced model with batched requests through
the slot scheduler (deliverable b).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "qwen2-0.5b", "--reduced", "--requests", "6",
                "--slots", "4", "--max-new", "12"] + sys.argv[1:])
