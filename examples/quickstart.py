"""Quickstart: the paper's Broken-Booth multiplier end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MulSpec, bbm_type0, characterize, to_signed
from repro.core.hwmodel import area, power, tmin
from repro.dsp import make_signals, run_filter_case
from repro.kernels import bbm_matmul


def main():
    # 1. the approximate product itself
    wl, vbl = 12, 9
    a, b = jnp.int32(1234), jnp.int32(-567 & 0xFFF)
    exact = int(to_signed(a, wl)) * int(to_signed(b, wl))
    approx = int(bbm_type0(a, b, wl, vbl))
    print(f"1234 x -567 @ WL={wl}, VBL={vbl}: exact={exact} approx={approx} "
          f"error={approx - exact}")

    # 2. its statistics (paper Table I methodology, exhaustive 2^24)
    st = characterize(MulSpec("bbm0", wl, vbl))
    print(f"exhaustive: {st.row()}")

    # 3. the modeled hardware win
    spec0 = MulSpec("bbm0", wl, 0)
    spec = MulSpec("bbm0", wl, vbl)
    print(f"power -{100 * (1 - power(spec) / power(spec0)):.1f}%  "
          f"area -{100 * (1 - area(spec) / area(spec0)):.1f}%  "
          f"tmin {tmin(spec):.2f}ns vs {tmin(spec0):.2f}ns")

    # 4. a whole DSP system using it (paper §III.C)
    sig = make_signals(n=1 << 13)
    snr_exact = run_filter_case(MulSpec("booth", 16, 0), sig)
    snr_approx = run_filter_case(MulSpec("bbm0", 16, 13), sig)
    print(f"30-tap FIR: SNR {snr_exact:.2f} dB -> {snr_approx:.2f} dB "
          f"with Broken-Booth multipliers (VBL=13)")

    # 5. the Pallas TPU kernel (bit-exact emulation, interpret mode on CPU)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 1 << wl, (32, 64)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 1 << wl, (64, 32)), jnp.int32)
    y = bbm_matmul(x, w, wl=wl, vbl=vbl, bm=16, bk=32, bn=16)
    print(f"bbm_matmul kernel: {y.shape} int32, sample {int(y[0, 0])}")


if __name__ == "__main__":
    main()
