"""Paper §III.C reproduction: the 30-tap FIR filter testbed, end to end.

    PYTHONPATH=src python examples/fir_filter_demo.py
"""
from repro.core.multipliers import MulSpec
from repro.core.hwmodel import fir_power, quap, fir_area
from repro.dsp import FIR_DELAY, design_lowpass, fir_apply_fixed, \
    make_signals, run_filter_case, snr_db


def main():
    sig = make_signals()
    h = design_lowpass()
    print(f"SNR_in  = {snr_db(sig.d1, sig.x, 0):6.2f} dB (paper: -3.47)")
    print(f"SNR_out = {run_filter_case(None, sig):6.2f} dB double precision "
          f"(paper: 25.7)")
    print()
    print("VBL sweep at WL=16 (paper Fig. 8b):")
    base_p = fir_power(16, 0)
    base_a = fir_area(16, 0)
    for vbl in (0, 9, 11, 13, 15, 17):
        y = fir_apply_fixed(sig.x, h, MulSpec("bbm0", 16, vbl))
        s = snr_db(sig.d1, y, FIR_DELAY)
        p = fir_power(16, vbl)
        a = fir_area(16, vbl)
        q = quap(s, 100 * (1 - a / base_a), 100 * (1 - p / base_p)) \
            if vbl else float("nan")
        print(f"  VBL={vbl:2d}: SNR {s:6.2f} dB   power {p:.2f} mW "
              f"(-{100 * (1 - p / base_p):4.1f}%)   QUAP/1e4 {q / 1e4:6.2f}")


if __name__ == "__main__":
    main()
