"""Paper §III.C reproduction: the 30-tap FIR filter testbed, end to end,
plus the batched multi-channel filterbank subsystem on top of it.

    PYTHONPATH=src python examples/fir_filter_demo.py
"""
import numpy as np

from repro.core.multipliers import MulSpec
from repro.core.hwmodel import fir_power, quap, fir_area
from repro.dsp import FIR_DELAY, PrecodedBank, design_lowpass, fir_apply, \
    fir_apply_fixed, make_signals, run_filter_case, run_filterbank_case, \
    snr_db


def main():
    sig = make_signals()
    h = design_lowpass()
    print(f"SNR_in  = {snr_db(sig.d1, sig.x, 0):6.2f} dB (paper: -3.47)")
    print(f"SNR_out = {run_filter_case(None, sig):6.2f} dB double precision "
          f"(paper: 25.7)")
    print()
    print("VBL sweep at WL=16 (paper Fig. 8b):")
    base_p = fir_power(16, 0)
    base_a = fir_area(16, 0)
    for vbl in (0, 9, 11, 13, 15, 17):
        y = fir_apply_fixed(sig.x, h, MulSpec("bbm0", 16, vbl))
        s = snr_db(sig.d1, y, FIR_DELAY)
        p = fir_power(16, vbl)
        a = fir_area(16, vbl)
        q = quap(s, 100 * (1 - a / base_a), 100 * (1 - p / base_p)) \
            if vbl else float("nan")
        print(f"  VBL={vbl:2d}: SNR {s:6.2f} dB   power {p:.2f} mW "
              f"(-{100 * (1 - p / base_p):4.1f}%)   QUAP/1e4 {q / 1e4:6.2f}")

    print()
    print("Batched filterbank (4 channels, 2 tap banks, WL=16 VBL=13):")
    spec = MulSpec("bbm0", 16, 13)
    snrs = run_filterbank_case(spec, channels=4, n=1 << 12)
    for c, s in enumerate(snrs):
        print(f"  channel {c} (bank {c % 2}): SNR {s:6.2f} dB")

    print()
    print("Host vs Pallas-interpret backend (bit-exactness checkpoint):")
    x = np.stack([make_signals(n=1 << 11, seed=s).x for s in range(4)])
    banks = np.stack([h, design_lowpass(stop_weight=0.5)])
    hb = banks[[0, 1, 0, 1]]
    y_host = fir_apply(x, hb, spec, backend="host")
    y_kern = fir_apply(x, hb, spec, backend="pallas-interpret")
    print(f"  identical: {np.array_equal(y_host, y_kern)}")

    print()
    print("Precoded bank (decode phase hoisted out of the hot path):")
    bank = PrecodedBank(banks, spec)         # quantize + Booth-decode, once
    y_pre = fir_apply(x, bank.take([0, 1, 0, 1]),
                      backend="pallas-interpret")
    print(f"  identical to raw taps: {np.array_equal(y_kern, y_pre)}")

    print()
    print("Dot form (exact contraction on the matmul units + truncated "
          "rows):")
    # the identity behind it: bbm(a, b) == a*b - correction(a_low, digits)
    from repro.core.bbm import bbm_mul
    from repro.kernels import booth_correction, booth_precode
    from repro.kernels.booth_rows import split_signed
    rng = np.random.default_rng(7)
    a = rng.integers(0, 1 << 16, 4096)
    b = rng.integers(0, 1 << 16, 4096)
    a_s = split_signed(a, 16)[1]
    mag, neg = booth_precode(b, 16)
    b_s = np.where(b >= 1 << 15, b - (1 << 16), b)
    corr = np.asarray(booth_correction(a_s, mag, neg, wl=16, vbl=13,
                                       kind=0), np.int64)
    ident = np.array_equal(np.asarray(bbm_mul(a, b, 16, 13), np.int64),
                           np.asarray(a_s, np.int64) * b_s - corr)
    print(f"  identity bbm(a,b) == a*b - correction(a_low): {ident}")
    y_rows = fir_apply(x, bank.take([0, 1, 0, 1]), backend="host",
                       form="rows")
    y_dot = fir_apply(x, bank.take([0, 1, 0, 1]), backend="host",
                      form="dot")
    print(f"  dot form bit-identical to rows form: "
          f"{np.array_equal(y_rows, y_dot)}")


if __name__ == "__main__":
    main()
