"""Dot-level reference simulators (numpy + python ints, arbitrary precision).

These literally build the partial-product dot diagram of each multiplier —
row by row, bit by bit, with hardware sign-extension semantics — apply the
breaking/nullification to individual dots, and sum columns.  They are the
oracles the closed-form JAX implementations are tested against
(tests/test_core_multipliers.py), and double as the big-int path for
unsigned word lengths whose products overflow int32.

Slow and scalar on purpose.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "booth_rows_ref",
    "bbm_ref",
    "bam_ref",
    "kulkarni_ref",
]


def _signed(x: int, wl: int) -> int:
    x &= (1 << wl) - 1
    return x - (1 << wl) if x >= (1 << (wl - 1)) else x


def booth_rows_ref(a: int, b: int, wl: int):
    """Radix-4 Booth rows of the dot diagram as (row_bits, neg, shift) lists.

    row_bits is the value of the row *without* the S increment, represented
    as an infinite-precision two's-complement integer (sign extension
    implicit); for negative rows this is the one's complement -(mag*A)-1.
    """
    assert wl % 2 == 0
    a_s = _signed(a, wl)
    bu = b & ((1 << wl) - 1)
    rows = []
    prev = 0
    for i in range(wl // 2):
        b0 = (bu >> (2 * i)) & 1
        b1 = (bu >> (2 * i + 1)) & 1
        bm1 = prev
        prev = b1
        d = -2 * b1 + b0 + bm1
        neg = b1
        mag = abs(d)
        ones_comp = -(mag * a_s) - 1 if neg else mag * a_s
        rows.append((ones_comp, neg, 2 * i))
    return rows


def _floor_clear(x: int, m: int) -> int:
    """Zero the low m bits of an infinite two's-complement integer."""
    return (x >> m) << m


def bbm_ref(a: int, b: int, wl: int, vbl: int, kind: int) -> int:
    """Dot-level Broken-Booth product (python ints)."""
    rows = booth_rows_ref(a, b, wl)
    total = 0
    for ones_comp, neg, shift in rows:
        m = max(0, vbl - shift)
        if kind == 0:
            # two's complement formed first (+1 folded in), then broken
            full = ones_comp + 1 if neg else ones_comp
            total += _floor_clear(full, m) << shift
        elif kind == 1:
            # broken first; S dot at column `shift` dropped if shift < vbl
            t = _floor_clear(ones_comp, m)
            s = neg if m == 0 else 0
            total += (t + s) << shift
        else:
            raise ValueError(kind)
    return total


def bam_ref(a: int, b: int, wl: int, vbl: int, hbl: int = 0) -> int:
    """Dot-level BAM product (unsigned)."""
    au = a & ((1 << wl) - 1)
    bu = b & ((1 << wl) - 1)
    total = 0
    for i in range(wl):          # rows
        if i < hbl:
            continue
        if not (bu >> i) & 1:
            continue
        for j in range(wl):      # dots
            if i + j < vbl:
                continue
            if (au >> j) & 1:
                total += 1 << (i + j)
    return total


def _m2x2(x: int, y: int, approx: bool) -> int:
    if approx and x == 3 and y == 3:
        return 7
    return x * y


def kulkarni_ref(a: int, b: int, wl: int, k: int = 0) -> int:
    """Block-level Kulkarni product (unsigned) with the paper's K line."""
    assert wl % 2 == 0
    n = wl // 2
    au = a & ((1 << wl) - 1)
    bu = b & ((1 << wl) - 1)
    total = 0
    for i in range(n):
        for j in range(n):
            ai = (au >> (2 * i)) & 3
            bj = (bu >> (2 * j)) & 3
            col = 2 * (i + j)
            total += _m2x2(ai, bj, col + 3 < k) << col
    return total
