"""Core arithmetic: the paper's Broken-Booth multiplier and its comparands."""
from .booth import booth_digits, booth_mul_exact, num_pp_rows, to_signed, to_unsigned
from .bbm import bbm_mul, bbm_type0, bbm_type1
from .bam import bam_mul
from .kulkarni import kulkarni_mul
from .multipliers import EXACT, MULTIPLIERS, MulSpec, mul
from .errstats import ErrorStats, characterize, error_histogram
from .faults import FaultSpec, apply_acc_fault, apply_plane_faults
from .guards import GuardConfig, GuardReport
from .noise import NoiseModel, inject_dot_error, make_noise_model

__all__ = [
    "booth_digits", "booth_mul_exact", "num_pp_rows", "to_signed", "to_unsigned",
    "bbm_mul", "bbm_type0", "bbm_type1", "bam_mul", "kulkarni_mul",
    "EXACT", "MULTIPLIERS", "MulSpec", "mul",
    "ErrorStats", "characterize", "error_histogram",
    "FaultSpec", "apply_acc_fault", "apply_plane_faults",
    "GuardConfig", "GuardReport",
    "NoiseModel", "inject_dot_error", "make_noise_model",
]
