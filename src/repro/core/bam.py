"""Broken-Array Multiplier (Mahdiani et al., paper ref [1]).

Unsigned carry-save array multiplier with cells removed below the Horizontal
Breaking Level (HBL) and to the right of the Vertical Breaking Level (VBL).

Row i (i = 0..wl-1) holds dots a_j * b_i in columns i+j.  Breaking:
  * VBL: drop dots with column index  i + j < VBL
  * HBL: drop *rows* with i < HBL (the paper's comparison uses HBL = 0)

    p = sum_{i >= hbl} b_i * ( a & ~(2^{max(0, vbl-i)} - 1) ) * 2^i

The paper notes BAM and its signed counterpart have identical MSE; we follow
the paper and compare on the unsigned version, mapping signed inputs through
their magnitude when used inside signed datapaths (see multipliers.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .booth import to_unsigned

__all__ = ["bam_mul"]


@partial(jax.jit, static_argnames=("wl", "vbl", "hbl"))
def bam_mul(a, b, wl: int, vbl: int, hbl: int = 0):
    """BAM product of unsigned wl-bit a, b (int32 in/out, 2*wl-bit result)."""
    au = to_unsigned(a, wl)[..., None]
    bu = to_unsigned(b, wl)[..., None]
    i = jnp.arange(wl, dtype=jnp.int32)
    b_i = (bu >> i) & 1
    m = jnp.maximum(0, vbl - i)
    a_masked = au & ~((jnp.int32(1) << m) - 1)
    row = jnp.where(i >= hbl, b_i * a_masked, 0)
    return jnp.sum(row << i, axis=-1)
