"""Error-Tolerant Multiplier (Kyaw, Goh & Yeo — paper ref [5]).

ETM splits the wl-bit operands at position ``split`` into a multiplication
part (high bits) and a non-multiplication part (low bits):

  * if EITHER operand's high part is non-zero: the high parts are multiplied
    exactly (shifted by 2*split) and the low-part product is *approximated*
    column-wise: approx_low[i] = OR of the (a_j AND b_k) dots on column i,
    then all lower columns are set to 1 from the highest active column down
    (the paper's "set remaining bits to 1" rule, which bounds relative
    error);
  * otherwise both high parts are zero and the low parts are multiplied
    exactly (small numbers keep full precision).

The paper reported >50% power saving for a 12-bit ETM; we include it as an
extra comparand beyond the three designs the Broken-Booth paper itself
synthesizes.  Power/area use the dot-inventory model: the low half's
multiplier array is replaced by OR chains (modeled at 15% of a dot's cost).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .booth import to_unsigned

__all__ = ["etm_mul"]


@partial(jax.jit, static_argnames=("wl", "split"))
def etm_mul(a, b, wl: int, split: int = 0):
    """ETM product of unsigned wl-bit a, b.  split=0 -> exact multiplier."""
    if split == 0:
        return to_unsigned(a, wl) * to_unsigned(b, wl)
    au = to_unsigned(a, wl)
    bu = to_unsigned(b, wl)
    mask_lo = (1 << split) - 1
    a_hi, a_lo = au >> split, au & mask_lo
    b_hi, b_lo = bu >> split, bu & mask_lo

    exact_small = au * bu                       # used when both highs zero

    # approximate low-part product: column-wise OR of partial products over
    # the 2*split - 1 usable columns, then fill 1s below the leading one.
    cols = jnp.arange(2 * split - 1)

    def col_or(c):
        j = jnp.arange(split)
        k = c - j
        valid = (k >= 0) & (k < split)
        aj = (a_lo[..., None] >> j) & 1
        bk = (b_lo[..., None] >> jnp.clip(k, 0, split - 1)) & 1
        return jnp.any(jnp.where(valid, (aj & bk) == 1, False), axis=-1)

    bits = jnp.stack([col_or(c) for c in range(2 * split - 1)],
                     axis=-1)                   # (..., 2*split-1)
    # fill: bit i becomes 1 if any column >= i is 1
    filled = jnp.cumsum(bits[..., ::-1].astype(jnp.int32), axis=-1)[..., ::-1] > 0
    low_approx = jnp.sum(filled.astype(jnp.int32) << cols, axis=-1)

    # high-part exact product plus cross terms approximated by the paper's
    # truncation: (a_hi*b) and (b_hi*a_lo) at full precision of high columns
    big = ((a_hi * b_hi) << (2 * split)) \
        + ((a_hi * b_lo + b_hi * a_lo) << split) + low_approx
    both_small = (a_hi == 0) & (b_hi == 0)
    return jnp.where(both_small, exact_small, big)
