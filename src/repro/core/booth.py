"""Radix-4 modified Booth recoding and the exact Booth multiplier.

All functions are pure JAX, vectorized over arbitrary leading batch dims, and
operate on signed two's-complement integers of word length ``wl`` carried in
int32 (wl <= 16 keeps every intermediate, including the 2*wl-bit product,
inside int32 for the magnitude and int64 nowhere).

Booth digit conventions follow Weste & Harris (paper ref [10]):

    triplet (b_{2i+1}, b_{2i}, b_{2i-1}) with b_{-1} = 0
    d_i   = -2*b_{2i+1} + b_{2i} + b_{2i-1}        in {-2,-1,0,1,2}
    neg_i = b_{2i+1}                               ("S" dot of row i)

``neg_i`` is the *hardware* sign/increment flag: the triplet 111 yields
d_i = 0 but neg_i = 1 (the "negative zero" row: all-ones one's-complement row
plus an S increment, summing to zero).  Type1 truncation exposes this row;
Type0 and the exact multiplier do not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "num_pp_rows",
    "booth_digits",
    "booth_mul_exact",
    "to_signed",
    "to_unsigned",
]


def num_pp_rows(wl: int) -> int:
    """Number of radix-4 Booth partial products for an even word length."""
    if wl % 2 != 0:
        raise ValueError(f"modified Booth needs an even word length, got {wl}")
    return wl // 2


def to_signed(x, wl: int):
    """Reinterpret the low ``wl`` bits of ``x`` as a signed integer."""
    x = jnp.asarray(x, jnp.int32)
    mask = (1 << wl) - 1
    x = x & mask
    sign = 1 << (wl - 1)
    return jnp.where(x >= sign, x - (1 << wl), x)


def to_unsigned(x, wl: int):
    """Low ``wl`` bits of ``x`` as a non-negative integer."""
    return jnp.asarray(x, jnp.int32) & ((1 << wl) - 1)


def booth_digits(b, wl: int):
    """Radix-4 Booth digits and hardware neg flags of ``b``.

    Returns ``(d, neg)``, each of shape ``b.shape + (wl//2,)``; ``d`` in
    {-2..2} (int32) and ``neg`` in {0,1} (int32, the raw b_{2i+1} bit).
    """
    n = num_pp_rows(wl)
    bu = to_unsigned(b, wl)[..., None]                     # (..., 1)
    i = jnp.arange(n, dtype=jnp.int32)                     # (n,)
    b_hi = (bu >> (2 * i + 1)) & 1
    b_mid = (bu >> (2 * i)) & 1
    # b_{2i-1}: for i=0 this is the implicit 0.
    b_lo = jnp.where(i == 0, 0, (bu >> jnp.maximum(2 * i - 1, 0)) & 1)
    d = -2 * b_hi + b_mid + b_lo
    return d.astype(jnp.int32), b_hi.astype(jnp.int32)


def booth_mul_exact(a, b, wl: int):
    """Exact signed product via Booth recoding: sum_i d_i * a * 4**i.

    Equals ``to_signed(a) * to_signed(b)`` for all wl-bit inputs; exists so
    that the approximate variants share one recoding code path and so tests
    can cross-check the recoding itself.
    """
    a_s = to_signed(a, wl)[..., None]
    d, _ = booth_digits(b, wl)
    n = num_pp_rows(wl)
    weight = (jnp.int32(1) << (2 * jnp.arange(n, dtype=jnp.int32)))
    return jnp.sum(d * a_s * weight, axis=-1)
