"""Keyed, deterministic hardware-fault injection for the Broken-Booth
datapath.

The paper trades *controlled* error for power; a deployment also sees
*uncontrolled* error — silicon defects and transient upsets — and the
approximate-multiplier literature evaluates designs under exactly those
(Masadeh et al.; Wu et al., "A Survey on Approximate Multiplier Designs
for Energy Efficiency").  This module is the software half of that axis:
a ``FaultSpec`` names a fault site, model and rate, and every mask it
draws is a pure function of ``(spec.seed, site indices)`` via
``jax.random`` — the same spec injects the *same* faults into the
dot-form datapath (``kernels.bbm_matmul``) and the scalar oracle
(``kernels.ref``), which is what keeps fault-injected dot-vs-oracle
equality ``assert_array_equal``, the repo's contract idiom.

Fault sites (``target``):

  "plane"  the radix-4 Booth digit planes of the multiplier operand —
           the partial-product generator's control lines.  Each digit is
           three stored bits: the magnitude select ``(mag_lo, mag_hi)``
           (one-hot-ish code for {0, A, 2A}) and the sign flag ``neg``.
           ``lane`` picks which line is faulty; ``rows`` restricts the
           site to the truncated correction rows (``"corr"`` — the rows
           the VBL nullification already degrades) or all rows.  A fault
           that drives the select to the unused ``11`` code resolves to
           the 2A line (the select saturates), so faulted planes stay in
           the decode domain every accumulate form understands.

  "acc"    one bit of the int32 accumulator: the per-chunk partial sum
           of the scaled contraction is XORed with a keyed rate-``p``
           mask at bit ``bit`` — a transient upset in the adder tree.
           Keyed per (chunk index, output element), so the dot form's
           ``lax.scan`` chunks and the oracle's python chunk loop draw
           identical masks.

Fault models (``model``):

  "flip"    transient: each cell flips independently with rate ``p``
  "stuck0"  defect: a keyed fraction ``p`` of cells reads 0 permanently
  "stuck1"  defect: a keyed fraction ``p`` of cells reads 1 permanently

``FaultSpec()`` (rate 0) is the no-fault spec: every application is a
no-op and the datapath is bit-identical to the unfaulted one — pinned by
tests/test_faults.py.  The dataclass is frozen/hashable so it can ride
jit static argnames.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["FaultSpec", "apply_acc_fault", "apply_plane_faults",
           "plane_fault_mask"]

_LANES = ("mag_lo", "mag_hi", "neg", "all")
_MODELS = ("flip", "stuck0", "stuck1")
_TARGETS = ("plane", "acc")
_ROWS = ("all", "corr")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault site + model + rate, deterministically keyed by ``seed``."""
    target: str = "plane"     # "plane" | "acc"
    model: str = "flip"       # "flip" | "stuck0" | "stuck1"
    p: float = 0.0            # fault rate (flip) / defect coverage (stuck)
    lane: str = "all"         # plane: "mag_lo" | "mag_hi" | "neg" | "all"
    rows: str = "all"         # plane: "all" | "corr" (truncated rows only)
    bit: int = 12             # acc: accumulator bit the upset lands on
    seed: int = 0             # keys every mask draw

    def __post_init__(self):
        if self.target not in _TARGETS:
            raise ValueError(f"unknown fault target {self.target!r}")
        if self.model not in _MODELS:
            raise ValueError(f"unknown fault model {self.model!r}")
        if self.lane not in _LANES:
            raise ValueError(f"unknown plane lane {self.lane!r}")
        if self.rows not in _ROWS:
            raise ValueError(f"unknown row selector {self.rows!r}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault rate must be in [0, 1], got {self.p}")
        if not 0 <= self.bit < 31:
            raise ValueError(f"accumulator bit must be in [0, 31), "
                             f"got {self.bit}")

    @property
    def enabled(self) -> bool:
        return self.p > 0.0


def _key(spec: FaultSpec, *folds: int):
    k = jax.random.key(spec.seed)
    for f in folds:
        k = jax.random.fold_in(k, f)
    return k


def plane_fault_mask(spec: FaultSpec, shape, lane_idx: int):
    """Boolean fault-site mask for one plane bit-lane, keyed and pure.

    The draw depends only on ``(spec.seed, lane_idx, shape)`` — never on
    the data — so the datapath and the oracle, handed the same spec and
    the same (wl//2, K, N) plane shape, fault the same cells.
    """
    return jax.random.bernoulli(_key(spec, 17, lane_idx), spec.p, shape)


def _fault_bit(bitval, mask, model: str):
    """Apply one fault model to a 0/1 bit plane at the masked cells."""
    if model == "flip":
        return jnp.where(mask, 1 - bitval, bitval)
    if model == "stuck0":
        return jnp.where(mask, 0, bitval)
    return jnp.where(mask, 1, bitval)       # stuck1


def apply_plane_faults(mag, neg, spec: FaultSpec | None, *, vbl: int = 0):
    """Faulted ``(mag, neg)`` digit planes; identity for a disabled spec.

    ``mag``/``neg`` are ``booth_precode`` planes of shape
    ``(wl//2, ...)``.  The stored encoding is faulted per bit-lane
    (``mag_lo``, ``mag_hi``, ``neg``); a select driven to the unused
    ``11`` magnitude code saturates to the 2A line (``mag = 2``), so the
    result stays inside the {0, 1, 2} x {0, 1} domain the accumulate
    forms and ``_MOD_BRANCHES`` enumerate.  ``rows="corr"`` confines the
    site to the ``ceil(vbl/2)`` truncated correction rows (pass the
    operating ``vbl``); rows above them stay clean.
    """
    if spec is None or not spec.enabled or spec.target != "plane":
        return mag, neg
    mag_lo, mag_hi = mag & 1, (mag >> 1) & 1
    lanes = {"mag_lo": mag_lo, "mag_hi": mag_hi, "neg": neg}
    for i, name in enumerate(("mag_lo", "mag_hi", "neg")):
        if spec.lane not in (name, "all"):
            continue
        mask = plane_fault_mask(spec, jnp.shape(mag), i)
        if spec.rows == "corr":
            n_corr = (vbl + 1) // 2       # num_corr_rows sans the row cap
            row_ok = (jnp.arange(jnp.shape(mag)[0]) < n_corr
                      ).reshape((-1,) + (1,) * (len(jnp.shape(mag)) - 1))
            mask = mask & row_ok
        lanes[name] = _fault_bit(lanes[name], mask, spec.model)
    new_mag = jnp.minimum(lanes["mag_lo"] + 2 * lanes["mag_hi"], 2)
    return new_mag.astype(mag.dtype), lanes["neg"].astype(neg.dtype)


def apply_acc_fault(acc, spec: FaultSpec | None, chunk_idx: int = 0):
    """XOR a keyed rate-``p`` upset mask into accumulator bit ``bit``.

    ``acc`` is the int32 per-chunk partial of the scaled contraction;
    ``chunk_idx`` folds the K-chunk index into the key so every chunk
    draws independent upsets yet both schedules (the datapath's
    ``lax.scan`` and the oracle's python loop) draw the *same* ones.
    Identity for a disabled or non-"acc" spec.  XOR never overflows, so
    the faulted partial is still a well-defined int32 that both paths
    cast to float32 identically.
    """
    if spec is None or not spec.enabled or spec.target != "acc":
        return acc
    mask = jax.random.bernoulli(_key(spec, 23, chunk_idx), spec.p,
                                jnp.shape(acc))
    return acc ^ (mask.astype(jnp.int32) << spec.bit)
