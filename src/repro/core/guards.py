"""Runtime numeric guards for the serving datapath.

The datapath's *static* contracts (the int32 envelope, the wl-bit code
range) are enforced at trace time where possible; this module adds the
*runtime* half a deployment needs: checks that run on the actual values
flowing through a jitted program, plus host-side monitors the serving
engines consult per flush / per decode step.  Three guard families:

  * finite guards — NaN/Inf detection on outputs (logits, filter
    samples), with per-row granularity so one poisoned request trips
    alone (``finite_rows``).
  * envelope guards — the wl-bit code range and the scaled-accumulator
    bound, written as ``jax.experimental.checkify`` checks so they
    survive ``jit`` (a plain python assert on a tracer cannot); run a
    checked function through ``checkify_call`` and an out-of-envelope
    value raises on the host with the check's message.
  * error-budget monitor — compares the approximate output against an
    exact reference (sampled, the caller decides how often) and trips
    when the mean absolute error leaves the configured budget: the
    "accuracy SLO" counterpart of the paper's fixed error analysis.

Every engine-facing check folds into one structured ``GuardReport``
(which guards ran, which tripped, per-row verdicts), so degradation
policies — re-serve on the exact datapath, quarantine, fail the
request — branch on a value, not on string parsing.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["GuardConfig", "GuardReport", "checkify_call",
           "code_range_check", "finite_rows", "guard_rows",
           "scaled_bound_check"]


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Which runtime guards an engine runs, and the error budget.

    ``budget_every = 0`` disables the (costly: one extra exact forward)
    budget audit; ``N > 0`` audits every Nth flush / decode step.  The
    budget is mean absolute error per audited row against the exact
    datapath — ``None`` disables even on audited rows.
    """
    finite: bool = True
    envelope: bool = True
    budget_abs: Optional[float] = None
    budget_every: int = 0

    @property
    def budget_active(self) -> bool:
        return self.budget_every > 0 and self.budget_abs is not None


@dataclasses.dataclass
class GuardReport:
    """Structured verdict of one guarded flush / decode step.

    ``row_ok`` carries the per-row (per-channel / per-slot) verdict the
    degradation policy acts on; ``tripped`` names every guard that
    failed ("finite", "budget"); ``nonfinite`` counts bad elements and
    ``budget_err`` is the worst audited per-row mean absolute error.
    """
    ok: bool = True
    row_ok: Optional[np.ndarray] = None
    tripped: Tuple[str, ...] = ()
    nonfinite: int = 0
    budget_err: Optional[float] = None

    def trip(self, name: str):
        self.ok = False
        if name not in self.tripped:
            self.tripped = self.tripped + (name,)


def finite_rows(y) -> np.ndarray:
    """Per-row finiteness verdict: (rows,) bool, True = every element
    finite.  ``y`` is host- or device-side, (rows, ...); reduction is
    over all trailing axes."""
    arr = np.asarray(y)
    return np.isfinite(arr).reshape(arr.shape[0], -1).all(axis=-1)


def guard_rows(y, cfg: GuardConfig, *, y_exact=None) -> GuardReport:
    """Run the configured host-side guards over a (rows, ...) output.

    ``y_exact``: exact-datapath reference for the same rows — pass it on
    audited flushes/steps only (the caller owns the sampling cadence);
    when present and a budget is configured, rows whose mean absolute
    error exceeds ``budget_abs`` trip the budget guard.  Returns a
    ``GuardReport`` whose ``row_ok`` masks the rows a degradation policy
    should re-serve or fail.
    """
    arr = np.asarray(y)
    rep = GuardReport(row_ok=np.ones(arr.shape[0], bool))
    if cfg.finite:
        fin = finite_rows(arr)
        if not fin.all():
            rep.trip("finite")
            rep.nonfinite = int((~np.isfinite(arr)).sum())
            rep.row_ok &= fin
    if y_exact is not None and cfg.budget_abs is not None:
        ref = np.asarray(y_exact, np.float64)
        err = np.abs(arr.astype(np.float64) - ref)
        per_row = err.reshape(err.shape[0], -1).mean(axis=-1)
        # a non-finite row already tripped above; keep the budget verdict
        # meaningful for the finite rows
        per_row = np.where(np.isfinite(per_row), per_row, np.inf)
        rep.budget_err = float(per_row.max())
        over = per_row > cfg.budget_abs
        if over.any():
            rep.trip("budget")
            rep.row_ok &= ~over
    return rep


# ------------------------------------------------- checkify-wired (in-jit)
def code_range_check(codes, wl: int, what: str = "codes"):
    """In-jit guard: every quantized code inside the signed wl-bit range.

    A ``checkify.check``, so it survives ``jit``: call inside the traced
    function and run it through ``checkify_call``.  The quantizer clips,
    so a trip means the datapath was handed codes it never produced —
    a corrupted cache entry, a fault-injection overreach, an integration
    bug.
    """
    import jax.numpy as jnp
    from jax.experimental import checkify
    lim = 1 << (wl - 1)
    # wl/lim are static python ints — bake them into the message (checkify
    # format args must be arrays)
    checkify.check(jnp.all((codes >= -lim) & (codes < lim)),
                   f"{what} outside the signed {wl}-bit envelope "
                   f"[{-lim}, {lim - 1}]")


def scaled_bound_check(acc, bound: int, what: str = "accumulator"):
    """In-jit guard: |scaled partial| within the dot form's int32 bound.

    ``bound`` is ``booth_rows.dotform_scaled_bound`` (or any caller
    bound); the check fires when the accumulator leaves it — the runtime
    counterpart of the static envelope assertion, catching what static
    analysis cannot (faulted planes, corrupted codes).
    """
    import jax.numpy as jnp
    from jax.experimental import checkify
    checkify.check(jnp.max(jnp.abs(acc)) <= bound,
                   f"{what} left the int32 envelope (bound {int(bound)})")


def checkify_call(fn, *args, **kwargs):
    """Run ``fn`` (which may contain checkify checks) under jit and raise
    any tripped check on the host.

    ``checkify.checkify`` functionalizes the checks into an error value
    that flows through jit; ``throw()`` re-raises it host-side — the
    piece that makes the envelope guards usable from a serving loop
    around compiled steps.  Returns ``fn``'s output when no check trips.
    """
    import jax
    from jax.experimental import checkify
    err, out = jax.jit(checkify.checkify(fn))(*args, **kwargs)
    err.throw()
    return out
