"""Registry of approximate multipliers behind one uniform interface.

A multiplier spec is ``MulSpec(name, wl, param, kind)``:

  name   one of {"booth", "bbm0", "bbm1", "bam", "kulkarni"}
  wl     word length of both operands (even)
  param  precision knob: VBL for booth-family/BAM, K for kulkarni, ignored
         for exact booth
  hbl    BAM-only horizontal breaking level (paper comparison uses 0)

``mul(spec)(a, b)`` maps int32 arrays of wl-bit operands to int32 products.
Signed semantics: booth/bbm take two's-complement signed operands natively.
BAM/Kulkarni are unsigned designs; for use inside signed datapaths we follow
the paper ("no difference between BAM and its signed counterpart, in terms of
MSE") and apply them sign-magnitude: p = sign(a)*sign(b) * m(|a|, |b|).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax.numpy as jnp

from .bam import bam_mul
from .bbm import bbm_type0, bbm_type1
from .booth import booth_mul_exact, to_signed
from .etm import etm_mul
from .kulkarni import kulkarni_mul

__all__ = ["MulSpec", "mul", "MULTIPLIERS", "EXACT"]


@dataclasses.dataclass(frozen=True)
class MulSpec:
    name: str = "booth"
    wl: int = 16
    param: int = 0          # VBL or K
    hbl: int = 0            # BAM only

    def __post_init__(self):
        if self.name not in MULTIPLIERS:
            raise ValueError(f"unknown multiplier {self.name!r}")
        if self.wl % 2 != 0:
            raise ValueError("word length must be even")

    @property
    def is_exact(self) -> bool:
        """Does this spec reduce to the exact signed product?

        ``booth`` ignores both knobs and is always exact; ``hbl`` only
        exists for ``bam``; every other design is exact iff its precision
        knob is 0.  (The old one-liner mixed ``and``/``or`` without parens
        and misclassified e.g. booth at param != 0.)
        """
        if self.name == "booth":
            return True
        if self.name == "bam":
            return self.param == 0 and self.hbl == 0
        return self.param == 0


def _signed_wrap(unsigned_fn: Callable, a, b, wl: int, **kw):
    a_s = to_signed(a, wl)
    b_s = to_signed(b, wl)
    sign = jnp.sign(a_s) * jnp.sign(b_s)
    return sign * unsigned_fn(jnp.abs(a_s), jnp.abs(b_s), wl=wl, **kw)


MULTIPLIERS = {
    "booth": lambda a, b, wl, param, hbl: booth_mul_exact(a, b, wl),
    "bbm0": lambda a, b, wl, param, hbl: bbm_type0(a, b, wl, param),
    "bbm1": lambda a, b, wl, param, hbl: bbm_type1(a, b, wl, param),
    "bam": lambda a, b, wl, param, hbl: _signed_wrap(
        partial(bam_mul, hbl=hbl), a, b, wl, vbl=param),
    "kulkarni": lambda a, b, wl, param, hbl: _signed_wrap(
        kulkarni_mul, a, b, wl, k=param),
    "etm": lambda a, b, wl, param, hbl: _signed_wrap(
        etm_mul, a, b, wl, split=param),
}

EXACT = MulSpec("booth", 16, 0)


def mul(spec: MulSpec) -> Callable:
    """Return f(a, b) -> approximate signed product for the given spec."""
    fn = MULTIPLIERS[spec.name]
    return lambda a, b: fn(a, b, spec.wl, spec.param, spec.hbl)
