"""Broken-Booth Multiplier (the paper's contribution), Type0 and Type1.

Closed-form, vectorized integer formulas for the dot-diagram truncation of
Fig. 1.  Both are validated bit-for-bit against the dot-level simulator in
``ref_sim.py`` (tests/test_core_multipliers.py).

Semantics (columns are bit positions of the 2*wl-bit product; VBL nullifies
every dot in columns < VBL):

Type0 — rows carry d_i * A as a complete two's-complement value (the +1 of
the complement already folded in); zeroing the low ``m_i = max(0, VBL - 2i)``
bits of a two's-complement value is flooring toward -inf:

    p = sum_i floor(d_i * A / 2^m_i) * 2^m_i * 4^i

Type1 — negative rows are one's-complemented only; the S (+1) dot sits in
column 2i and is dropped when 2i < VBL.  Hardware's row value before the S is
``-(mag_i * A) - 1`` (one's complement, sign-extended); the "negative zero"
triplet (111) produces mag=0, neg=1: an all-ones row (-1) plus S:

    row_i = mag_i * A                 if neg_i == 0
          = -(mag_i * A) - 1         if neg_i == 1
    p = sum_i [ floor(row_i / 2^m_i) * 2^m_i + neg_i * (m_i == 0) ] * 4^i

VBL = 0 reduces both types to the exact Booth product.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .booth import booth_digits, num_pp_rows, to_signed

__all__ = ["bbm_mul", "bbm_type0", "bbm_type1"]


def _row_masks(wl: int, vbl: int):
    # int32-safety: |approx| <= |exact| + ceil(vbl/2)*2^vbl must fit in 31
    # bits.  The paper never exceeds vbl = wl - 1; we allow a wide margin.
    limit = 2 * wl - 6 if wl >= 14 else 2 * wl
    if not 0 <= vbl <= limit:
        raise ValueError(f"vbl={vbl} outside int32-safe range [0, {limit}] "
                         f"for wl={wl}")
    n = num_pp_rows(wl)
    i = jnp.arange(n, dtype=jnp.int32)
    m = jnp.maximum(0, vbl - 2 * i)                     # bits to clear per row
    two_m = jnp.int32(1) << m
    weight = jnp.int32(1) << (2 * i)
    return m, two_m, weight


def bbm_type0(a, b, wl: int, vbl: int):
    """Broken-Booth Type0 product of signed wl-bit a, b (int32 in/out)."""
    a_s = to_signed(a, wl)[..., None]
    d, _ = booth_digits(b, wl)
    _, two_m, weight = _row_masks(wl, vbl)
    rows = d * a_s                                       # d_i * A, signed
    trunc = jnp.floor_divide(rows, two_m) * two_m
    return jnp.sum(trunc * weight, axis=-1)


def bbm_type1(a, b, wl: int, vbl: int):
    """Broken-Booth Type1 product of signed wl-bit a, b (int32 in/out)."""
    a_s = to_signed(a, wl)[..., None]
    d, neg = booth_digits(b, wl)
    m, two_m, weight = _row_masks(wl, vbl)
    mag = jnp.abs(d)
    pos_val = mag * a_s
    row = jnp.where(neg == 1, -pos_val - 1, pos_val)
    trunc = jnp.floor_divide(row, two_m) * two_m
    s_dot = jnp.where((neg == 1) & (m == 0), 1, 0)
    return jnp.sum((trunc + s_dot) * weight, axis=-1)


@partial(jax.jit, static_argnames=("wl", "vbl", "kind"))
def bbm_mul(a, b, wl: int, vbl: int, kind: int = 0):
    """Dispatcher: kind=0 -> Type0, kind=1 -> Type1."""
    if kind == 0:
        return bbm_type0(a, b, wl, vbl)
    if kind == 1:
        return bbm_type1(a, b, wl, vbl)
    raise ValueError(f"unknown BBM kind {kind}")
