"""Underdesigned 2x2-block multiplier (Kulkarni et al., paper ref [3]) with
the paper's added K parameter.

The 2x2 inaccurate building block computes a*b exactly except 3*3 -> 7
(instead of 9), saving the fourth output bit.  A wl-bit unsigned multiplier
is composed of (wl/2)^2 such blocks on 2-bit digits:

    a = sum_i A_i 4^i,  b = sum_j B_j 4^j   (A_i, B_j in 0..3)
    p = sum_{i,j} m(A_i, B_j) * 4^{i+j}

Block (i,j) spans product columns 2(i+j) .. 2(i+j)+3.  Following the paper's
Fig. 4, blocks lying *entirely* to the right of the vertical line at column K
are approximate, the rest exact:

    m = m_approx  if 2*(i+j) + 3 < K  else  A_i * B_j

K = 0 gives the exact multiplier; larger K trades accuracy for power.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .booth import to_unsigned

__all__ = ["kulkarni_mul"]


@partial(jax.jit, static_argnames=("wl", "k"))
def kulkarni_mul(a, b, wl: int, k: int = 0):
    """Kulkarni 2x2-block product of unsigned wl-bit a, b."""
    if wl % 2 != 0:
        raise ValueError("kulkarni multiplier needs an even word length")
    n = wl // 2
    au = to_unsigned(a, wl)[..., None]
    bu = to_unsigned(b, wl)[..., None]
    i = jnp.arange(n, dtype=jnp.int32)
    ai = (au >> (2 * i)) & 3                                # (..., n)
    bj = (bu >> (2 * i)) & 3
    ai = ai[..., :, None]                                   # (..., n, 1)
    bj = bj[..., None, :]                                   # (..., 1, n)
    exact = ai * bj
    approx = exact - 2 * ((ai == 3) & (bj == 3)).astype(jnp.int32)
    col = 2 * (i[:, None] + i[None, :])                     # (n, n) block LSB column
    use_approx = (col + 3) < k
    m = jnp.where(use_approx, approx, exact)
    return jnp.sum(m << col, axis=(-2, -1))
