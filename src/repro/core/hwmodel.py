"""Analytic hardware cost model (area / power / delay / PDP).

The paper reports Synopsys DC + PrimeTime numbers in 90 nm.  Without a
synthesis flow we model the hardware structurally and calibrate a small
number of global coefficients against the paper's own tables, then *report
model-vs-paper deltas* in the benchmarks (never silently substituting).

Structural inventory (radix-4 Booth, word length ``wl``, rows n = wl/2):

  * dot count        T(wl)       = n*(wl+1) - 1      (matches the paper's
                                   "36 bits out of 77" for wl=12, vbl=11)
  * nullified dots   Z(wl, vbl)  = sum_i max(0, vbl - 2i)
  * recoders         n
  * final CPA bits   2*wl - vbl

Area  = a_dot*(T - Z) + a_rec*n + a_cpa*(2wl - vbl)
Power = p_dot*sum_c r_c*(1 + phi*R_c) + p_rec*n + p_cpa*(2wl - vbl)
        where r_c = live rows feeding product column c and R_c = live dots in
        all columns right of c.  The phi term models glitch *propagation*:
        transitions generated on the right ripple left through the
        compressor tree, so truncating right-hand columns reduces switching
        activity in every remaining column — which is exactly the paper's
        observation that power falls faster than area.
Delay = t_rec + t_csa*log2(max_c r_c) + t_cpa*log2(2wl - vbl)

Coefficients (a_*, p_*, g) are least-squares fit to the eight Table II/III
mean reductions; delay terms to the two reported T_min values (1.21 ns
accurate / 1.13 ns approximate at wl=16).  The synthesis power/delay curve of
Fig. 3 is modeled with the standard sizing hyperbola P(T) ~ 1/(T - T_in).

BAM and Kulkarni get the same treatment on their own dot inventories so the
Fig. 5/6 PDP-vs-MSE comparison is like-for-like.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, Tuple

import numpy as np
from scipy.optimize import least_squares

from .multipliers import MulSpec

__all__ = [
    "PAPER_POWER_REDUCTION", "PAPER_AREA_REDUCTION", "PAPER_TABLE4",
    "dot_inventory", "area", "power", "tmin", "power_at", "pdp_avg",
    "fir_power", "quap",
]

# ----------------------------------------------------------------------------
# Paper ground truth used for calibration + benchmark comparison
# ----------------------------------------------------------------------------
# Table II / III mean reductions (%) for (wl, vbl=wl-1)
PAPER_POWER_REDUCTION: Dict[int, float] = {4: 28.0, 8: 56.3, 12: 58.6, 16: 57.4}
PAPER_AREA_REDUCTION: Dict[int, float] = {4: 19.7, 8: 33.4, 12: 41.8, 16: 41.6}
# Fig. 3 / §III.A
PAPER_TMIN_ACCURATE_NS = 1.21
PAPER_TMIN_APPROX_NS = 1.13
# Table IV: (wl, vbl) -> (snr_db, clock_ns, area_um2, power_mw)
PAPER_TABLE4 = {
    (16, 0): (25.35, 4.78, 1.22e5, 3.63),
    (16, 13): (25.0, 4.78, 1.07e5, 3.01),
    (14, 0): (23.1, 4.78, 1.13e5, 2.91),
}
FIR_TAPS = 30


# ----------------------------------------------------------------------------
# Structural inventories
# ----------------------------------------------------------------------------
def _booth_columns(wl: int, vbl: int) -> np.ndarray:
    """Live-row count r_c per product column c for the broken Booth array."""
    n = wl // 2
    cols = np.zeros(2 * wl, dtype=np.int64)
    for i in range(n):
        lo = max(2 * i, vbl)
        hi = min(2 * i + wl + 2, 2 * wl)          # row spans wl+2 dots
        if hi > lo:
            cols[lo:hi] += 1
    return cols


def _bam_columns(wl: int, vbl: int, hbl: int = 0) -> np.ndarray:
    cols = np.zeros(2 * wl, dtype=np.int64)
    for i in range(hbl, wl):
        lo = max(i, vbl)
        hi = i + wl
        if hi > lo:
            cols[lo:hi] += 1
    return cols


def _kulkarni_cells(wl: int, k: int) -> Tuple[float, float]:
    """(cell_cost, switch_cost) of the 2x2-block multiplier with line K.

    An approximate 2x2 block drops the MSB output and its AND plane
    (Kulkarni et al. report ~45% power saving per block); we model its
    cost as 0.55x an exact block, plus the compression tree of the block
    outputs (unaffected by K except through narrower columns).
    """
    n = wl // 2
    cells = switch = 0.0
    for i in range(n):
        for j in range(n):
            c = 0.55 if 2 * (i + j) + 3 < k else 1.0
            cells += c
            switch += c * (1 + 0.15 * (i + j))    # deeper columns glitch more
    return cells, switch


def dot_inventory(spec: MulSpec) -> Dict[str, float]:
    """Active/total dot counts + live-row column profile for a spec."""
    if spec.name in ("booth", "bbm0", "bbm1"):
        cols0 = _booth_columns(spec.wl, 0)
        cols = _booth_columns(spec.wl, 0 if spec.name == "booth" else spec.param)
        total = spec.wl // 2 * (spec.wl + 1) - 1
        nullified = sum(max(0, (spec.param if spec.name != "booth" else 0) - 2 * i)
                        for i in range(spec.wl // 2))
    elif spec.name == "bam":
        cols0 = _bam_columns(spec.wl, 0, 0)
        cols = _bam_columns(spec.wl, spec.param, spec.hbl)
        total = int(cols0.sum())
        nullified = total - int(cols.sum())
    elif spec.name == "etm":
        # low half replaced by OR chains (~15% of a dot), highs exact
        split = spec.param
        cols0 = _bam_columns(spec.wl, 0, 0)
        total = int(cols0.sum())
        low_dots = split * split
        active = float(total - low_dots + 0.15 * (2 * split - 1))
        return {"total": float(total), "active": active,
                "cols": _bam_columns(spec.wl, 0, 0), "cols0": cols0}
    elif spec.name == "kulkarni":
        cells0, _ = _kulkarni_cells(spec.wl, 0)
        cells, _ = _kulkarni_cells(spec.wl, spec.param)
        return {"total": 4 * cells0, "active": 4 * cells,
                "cols": np.array([]), "cols0": np.array([])}
    else:
        raise ValueError(spec.name)
    return {"total": float(total), "active": float(total - nullified),
            "cols": cols, "cols0": cols0}


# ----------------------------------------------------------------------------
# Calibrated model
# ----------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HwParams:
    a_dot: float
    a_rec: float
    a_cpa: float
    p_dot: float
    p_rec: float
    p_cpa: float
    phi: float        # glitch-propagation factor (per live right-hand dot)
    t_rec: float      # ns
    t_csa: float      # ns per log2 compressor level
    t_cpa: float      # ns per log2 CPA bit


def _propagated_activity(cols: np.ndarray, phi: float) -> float:
    """sum_c r_c * (1 + phi * live-dots-right-of-c)."""
    cols = cols.astype(np.float64)
    cum_right = np.concatenate([[0.0], np.cumsum(cols)[:-1]])
    return float(np.sum(cols * (1.0 + phi * cum_right)))


def _area_raw(p: "HwParams", wl: int, vbl: int) -> float:
    inv = dot_inventory(MulSpec("bbm0", wl, vbl))
    return (p.a_dot * inv["active"] + p.a_rec * (wl // 2)
            + p.a_cpa * (2 * wl - vbl))


def _power_raw(p: "HwParams", wl: int, vbl: int) -> float:
    cols = _booth_columns(wl, vbl)
    act = _propagated_activity(cols, p.phi)
    return p.p_dot * act + p.p_rec * (wl // 2) + p.p_cpa * (2 * wl - vbl)


@lru_cache(maxsize=1)
def calibrate() -> HwParams:
    """Fit global coefficients to the paper's Tables II/III + T_min pair."""
    wls = [4, 8, 12, 16]

    def area_res(x):
        a_rec, a_cpa = x
        p = HwParams(1.0, a_rec, a_cpa, 1.0, 0, 0, 0, 0, 0, 0)
        return [100 * (1 - _area_raw(p, wl, wl - 1) / _area_raw(p, wl, 0))
                - PAPER_AREA_REDUCTION[wl] for wl in wls]

    asol = least_squares(area_res, np.array([2.0, 1.0]),
                         bounds=([0, 0], [50, 50]))
    a_rec, a_cpa = asol.x

    def power_res(x):
        p_rec, p_cpa, phi = x
        p = HwParams(1.0, 0, 0, 1.0, p_rec, p_cpa, phi, 0, 0, 0)
        return [100 * (1 - _power_raw(p, wl, wl - 1) / _power_raw(p, wl, 0))
                - PAPER_POWER_REDUCTION[wl] for wl in wls]

    psol = least_squares(power_res, np.array([2.0, 1.0, 0.05]),
                         bounds=([0, 0, 0], [50, 50, 1.0]))
    p_rec, p_cpa, phi = psol.x

    # delay terms: two equations (accurate & approx T_min at wl=16), plus a
    # fixed recode latency of 0.15 ns (one gate level + wiring in 90 nm).
    t_rec = 0.15
    cols_acc = _booth_columns(16, 0)
    cols_app = _booth_columns(16, 15)

    def dres(x):
        t_csa, t_cpa = x
        da = t_rec + t_csa * np.log2(cols_acc.max()) + t_cpa * np.log2(32)
        dp = t_rec + t_csa * np.log2(cols_app.max()) + t_cpa * np.log2(32 - 15)
        return [da - PAPER_TMIN_ACCURATE_NS, dp - PAPER_TMIN_APPROX_NS]

    dsol = least_squares(dres, np.array([0.2, 0.1]), bounds=(0, 2))
    t_csa, t_cpa = dsol.x
    return HwParams(1.0, a_rec, a_cpa, 1.0, p_rec, p_cpa, phi,
                    t_rec, t_csa, t_cpa)


# ----------------------------------------------------------------------------
# Public model queries
# ----------------------------------------------------------------------------
def area(spec: MulSpec) -> float:
    """Relative area (a.u.); booth-family uses the calibrated fit."""
    p = calibrate()
    if spec.name in ("booth", "bbm0", "bbm1"):
        vbl = 0 if spec.name == "booth" else spec.param
        return _area_raw(p, spec.wl, vbl)
    inv = dot_inventory(spec)
    if spec.name == "bam":
        return p.a_dot * inv["active"] + p.a_cpa * (2 * spec.wl - spec.param)
    return p.a_dot * inv["active"] + p.a_cpa * 2 * spec.wl   # kulkarni


def power(spec: MulSpec) -> float:
    """Relative average power at a relaxed clock (a.u.)."""
    p = calibrate()
    if spec.name in ("booth", "bbm0", "bbm1"):
        vbl = 0 if spec.name == "booth" else spec.param
        pw = _power_raw(p, spec.wl, vbl)
        if spec.name == "bbm1":
            # Type1 drops whole row incrementers whose S dot is nullified:
            # a half-adder chain of ~(wl+2) bits, active on ~half the cycles
            # (P(neg row) = 1/2 under random inputs).
            n_dropped = sum(1 for i in range(spec.wl // 2)
                            if 2 * i < spec.param)
            pw -= 0.25 * (spec.wl + 2) * n_dropped * p.p_dot
        return pw
    inv = dot_inventory(spec)
    if spec.name == "bam":
        act = _propagated_activity(inv["cols"], p.phi)
        return p.p_dot * act + p.p_cpa * (2 * spec.wl - spec.param)
    if spec.name == "etm":
        inv2 = dot_inventory(spec)
        act = _propagated_activity(inv2["cols"], p.phi)
        frac = inv2["active"] / inv2["total"]
        return p.p_dot * act * frac + p.p_cpa * 2 * spec.wl
    _, switch = _kulkarni_cells(spec.wl, spec.param)
    return p.p_dot * 4 * switch + p.p_cpa * 2 * spec.wl


def tmin(spec: MulSpec) -> float:
    """Minimum achievable clock period (ns) under the delay model."""
    p = calibrate()
    if spec.name in ("booth", "bbm0", "bbm1"):
        vbl = 0 if spec.name == "booth" else spec.param
        cols = _booth_columns(spec.wl, vbl)
        cpa_bits = max(2 * spec.wl - vbl, 2)
    elif spec.name == "bam":
        cols = _bam_columns(spec.wl, spec.param, spec.hbl)
        cpa_bits = max(2 * spec.wl - spec.param, 2)
    elif spec.name == "etm":
        cols = _bam_columns(spec.wl, 0, 0)
        cpa_bits = 2 * spec.wl - spec.param
    else:  # kulkarni: ripple of 2x2 blocks ~ array of depth wl/2
        cols = np.array([max(spec.wl // 2, 2)])
        cpa_bits = 2 * spec.wl
    depth = max(float(cols.max()), 2.0)
    return p.t_rec + p.t_csa * np.log2(depth) + p.t_cpa * np.log2(cpa_bits)


def power_at(spec: MulSpec, t_ns: float) -> float:
    """Fig. 3 sizing curve: power grows hyperbolically approaching T_min."""
    t0 = tmin(spec)
    base = power(spec)
    # calibrated so P(2*Tmin) ~= base and P(Tmin) ~= 2.2*base (Fig. 3 shape)
    kappa = 0.35
    t_int = 0.75 * t0                      # intrinsic delay asymptote
    return base * (0.9 + kappa * (t0 - t_int) / max(t_ns - t_int, 1e-3))


def pdp_avg(spec: MulSpec, relaxed_ns: float = 1.75) -> float:
    """Average PDP of the paper's steps 2-4: min-delay PDP and 1.75 ns PDP."""
    t0 = tmin(spec)
    pdp_fast = power_at(spec, t0) * t0
    pdp_slow = power_at(spec, relaxed_ns) * relaxed_ns
    return 0.5 * (pdp_fast + pdp_slow)


# ----------------------------------------------------------------------------
# FIR filter power (Table IV calibration)
# ----------------------------------------------------------------------------
@lru_cache(maxsize=1)
def _fir_coeffs() -> Tuple[float, float, float]:
    """Solve P_filter = u*30*Pm(wl,vbl) + v*wl + w through Table IV's cases."""
    rows = []
    rhs = []
    for (wl, vbl), (_, _, _, pw) in PAPER_TABLE4.items():
        rows.append([FIR_TAPS * power(MulSpec("bbm0", wl, vbl)), wl, 1.0])
        rhs.append(pw)
    sol, *_ = np.linalg.lstsq(np.array(rows), np.array(rhs), rcond=None)
    return tuple(sol)


def fir_power(wl: int, vbl: int) -> float:
    """Modeled FIR filter power (mW) for the paper's 30-tap filter."""
    u, v, w = _fir_coeffs()
    return u * FIR_TAPS * power(MulSpec("bbm0", wl, vbl)) + v * wl + w


def fir_area(wl: int, vbl: int) -> float:
    """Modeled FIR area (um^2), scaled off case 1 of Table IV."""
    ref_area = PAPER_TABLE4[(16, 0)][2]
    # multipliers are ~55% of filter area at wl=16 (from case1 vs case3 slope)
    mult_frac = 0.55
    rel = area(MulSpec("bbm0", wl, vbl)) / area(MulSpec("bbm0", 16, 0))
    wl_frac = wl / 16.0
    return ref_area * (mult_frac * rel + (1 - mult_frac) * wl_frac)


def quap(snr_db: float, area_saving_pct: float, power_saving_pct: float) -> float:
    """QUAP = (SNR_out)^2 * area_saving(%) * power_saving(%) (paper Eq. 3)."""
    return (snr_db ** 2) * area_saving_pct * power_saving_pct
