"""Error characterization of approximate multipliers (paper §II.B).

Implements the paper's evaluation method: model the arithmetic behaviour and
apply either *all* possible input vectors exhaustively (2^(2*wl) pairs — the
paper's Table I uses wl=12, N = 2^24) or a random sample.  Reports the four
Table I statistics plus the error histogram of Fig. 2.

    error = approximate output - accurate output            (Eq. 1)
    MSE   = (1/N) * sum_i error(i)^2                        (Eq. 2)

The device computes raw int32 error vectors per chunk (vectorized over the
full second operand axis); moment accumulation happens on the host in
float64 so the Table I sums (up to ~1e15) are exact without enabling x64.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .booth import to_signed
from .multipliers import MulSpec, mul

__all__ = ["ErrorStats", "characterize", "error_histogram"]


@dataclasses.dataclass(frozen=True)
class ErrorStats:
    """Error moments of an approximate multiplier over a given input set."""
    mean: float
    mse: float
    prob: float          # P(error != 0)
    min: float
    max: float
    var: float
    n: int

    @property
    def std(self) -> float:
        return float(np.sqrt(max(self.var, 0.0)))

    def row(self) -> str:
        return (f"mean={self.mean:+.4g} mse={self.mse:.4g} "
                f"prob={self.prob:.4f} min={self.min:+.4g} max={self.max:+.4g}")


@partial(jax.jit, static_argnames=("name", "wl", "param", "hbl"))
def _err_vs_b(a_chunk, name, wl, param, hbl):
    """int32 error of a_chunk x (all 2^wl b values)."""
    spec = MulSpec(name, wl, param, hbl)
    b = jnp.arange(1 << wl, dtype=jnp.int32)
    a = a_chunk[:, None]
    return mul(spec)(a, b) - to_signed(a, wl) * to_signed(b, wl)


@partial(jax.jit, static_argnames=("name", "wl", "param", "hbl"))
def _err_pairs(a, b, name, wl, param, hbl):
    spec = MulSpec(name, wl, param, hbl)
    return mul(spec)(a, b) - to_signed(a, wl) * to_signed(b, wl)


def characterize(spec: MulSpec, *, exhaustive: Optional[bool] = None,
                 sample: int = 1 << 20, seed: int = 0,
                 chunk: int = 1 << 8) -> ErrorStats:
    """Characterize ``spec`` exhaustively (default for wl <= 12) or sampled."""
    wl = spec.wl
    if exhaustive is None:
        exhaustive = wl <= 12

    s = ss = nz = 0.0
    mn, mx = np.inf, -np.inf
    n = 0
    if exhaustive:
        for lo in range(0, 1 << wl, chunk):
            a_chunk = jnp.arange(lo, min(lo + chunk, 1 << wl), dtype=jnp.int32)
            err = np.asarray(
                _err_vs_b(a_chunk, spec.name, wl, spec.param, spec.hbl),
                dtype=np.float64)
            s += err.sum()
            ss += (err * err).sum()
            nz += np.count_nonzero(err)
            mn = min(mn, float(err.min()))
            mx = max(mx, float(err.max()))
            n += err.size
    else:
        rng = np.random.default_rng(seed)
        done = 0
        while done < sample:
            m = min(chunk * chunk, sample - done)
            a = jnp.asarray(rng.integers(0, 1 << wl, size=m, dtype=np.int32))
            b = jnp.asarray(rng.integers(0, 1 << wl, size=m, dtype=np.int32))
            err = np.asarray(
                _err_pairs(a, b, spec.name, wl, spec.param, spec.hbl),
                dtype=np.float64)
            s += err.sum()
            ss += (err * err).sum()
            nz += np.count_nonzero(err)
            mn = min(mn, float(err.min()))
            mx = max(mx, float(err.max()))
            done += m
            n += m
    mean = s / n
    mse = ss / n
    return ErrorStats(mean=mean, mse=mse, prob=nz / n, min=mn, max=mx,
                      var=mse - mean * mean, n=n)


def error_histogram(spec: MulSpec, bins: int = 81):
    """Fig. 2: percentage distribution of error normalized to 2^(2*wl - 1).

    Exhaustive over all pairs (use wl <= 10 as in the paper's figure); the
    bin range adapts to the observed error span (two passes).
    Returns (bin_centers_normalized, percentage).
    """
    wl = spec.wl
    norm = float(1 << (2 * wl - 1))
    st = characterize(spec)
    lo_e = st.min / norm
    hi_e = st.max / norm
    span = max(hi_e - lo_e, 1e-12)
    edges = np.linspace(lo_e - 0.02 * span, hi_e + 0.02 * span, bins + 1)
    counts = np.zeros(bins, dtype=np.float64)
    for lo in range(0, 1 << wl, 256):
        a_chunk = jnp.arange(lo, min(lo + 256, 1 << wl), dtype=jnp.int32)
        err = np.asarray(
            _err_vs_b(a_chunk, spec.name, wl, spec.param, spec.hbl),
            dtype=np.float64).ravel() / norm
        c, _ = np.histogram(err, bins=edges)
        counts += c
    pct = 100.0 * counts / counts.sum()
    centers = 0.5 * (edges[:-1] + edges[1:])
    return centers, pct
