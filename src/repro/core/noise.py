"""Statistical error-injection model (paper §II.B, ref [11]).

The paper analyzes the multiplier's output error as an additive white noise
source with a defined power level (Oppenheim & Schafer's quantization-noise
methodology).  We apply the same model *generatively*: for a dot product of
length K computed on approximate hardware, the accumulated error is
approximately Normal(K * mu, K * sigma^2) by CLT over the (near-independent)
per-product errors.

This is what makes the technique usable inside 100M..671B-parameter models:
characterize once (exhaustive/sampled, `errstats.characterize`), then inject
the calibrated noise around an *exact* MXU matmul.  Bit-exact emulation
(kernels/bbm_matmul.py) remains available to validate the noise model — see
tests/test_noise_model.py and tests/test_amm_bitexact.py, which check
injected moments against bit-exact runs.

Operand-scale correction: the characterized (mu, sigma) assume uniform
wl-bit operands.  Truncation error of row i is ~ d_i*A mod 2^m, whose moments
scale with the *multiplicand* magnitude distribution; for zero-mean inputs
narrower than full scale we scale mu and sigma by E|a|/E|a_full| (a first
order correction validated in tests to within a few percent for the
configurations used by the model layers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .errstats import ErrorStats, characterize
from .multipliers import MulSpec

__all__ = ["NoiseModel", "make_noise_model", "inject_dot_error"]

_CACHE: dict = {}


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """Calibrated additive-error model for one multiplier spec."""
    spec: MulSpec
    mean: float           # per-product error mean (int domain)
    var: float            # per-product error variance (int domain)

    def dot_moments(self, k: int) -> tuple:
        """(mean, std) of the error of a K-term dot product."""
        return k * self.mean, float(np.sqrt(k * self.var))


def make_noise_model(spec: MulSpec, *, sample: int = 1 << 20,
                     stats: Optional[ErrorStats] = None) -> NoiseModel:
    """Characterize (cached) and wrap as a NoiseModel."""
    key = (spec, sample)
    if key not in _CACHE:
        st = stats or characterize(spec, sample=sample)
        _CACHE[key] = NoiseModel(spec=spec, mean=st.mean, var=st.var)
    return _CACHE[key]


def inject_dot_error(y_int, key, model: NoiseModel, k: int,
                     amp_scale=1.0):
    """Add calibrated accumulated error to an exact int-domain dot product.

    y_int:     exact dot-product result in the integer (pre-descale) domain
    key:       PRNG key
    k:         dot-product length (number of accumulated products)
    amp_scale: operand-magnitude correction factor (E|a| ratio), may be a
               traced scalar.
    """
    mu = model.mean * k * amp_scale
    sigma = jnp.sqrt(jnp.maximum(model.var * k, 0.0)) * amp_scale
    noise = mu + sigma * jax.random.normal(key, y_int.shape, dtype=y_int.dtype)
    return y_int + noise
