"""Fixed-point FIR filtering built on approximate multipliers (paper §III.C).

The paper's application: a 30-tap-order Parks--McClellan low-pass filter
whose tap multipliers are replaced by Broken-Booth multipliers.  We model
the datapath bit-exactly:

  * input samples and coefficients quantized to Q(1, wl-1),
  * every tap product computed by the selected approximate multiplier
    (`core.multipliers`), with an optional per-product arithmetic right
    shift (the fixed-point MAC rescale),
  * products accumulated at full precision (the 2*wl + log2(taps) bit
    accumulator every sane FIR datapath carries; numerically exact here via
    float64 on the host — int products are < 2^31 so the sum of 31 of them is
    exact in float64's 53-bit mantissa).

``fir_apply`` is the one datapath entry point.  It accepts single signals
``(N,)`` or multi-channel filterbanks ``(C, N)`` with per-channel tap banks
``(C, taps)`` — as raw real taps or as a ``PrecodedBank`` — and dispatches
to one of three backends:

  backend="host"              per-tap shift-and-accumulate over jnp/numpy
                              closed forms (O(C*N) live memory on the hot
                              paths — exact numpy and in-envelope Booth
                              specs never materialize the (C, N, taps)
                              window); supports every registered
                              multiplier and both datapaths
                              ("full" / "wlbit")
  backend="pallas"            the Pallas TPU filterbank kernel
                              (``kernels.fir_bbm_bank_precoded``);
                              Booth-family specs only, compiled on TPU
  backend="pallas-interpret"  same kernel through the Pallas interpreter
                              (bit-exact validation on CPU)

Precoded-bank fast path: the tap bank is the Booth *multiplier* operand
and is constant across samples, blocks and requests, so its quantization
and radix-4 recode are hoisted out of the hot path entirely.
``PrecodedBank(h, spec)`` quantizes once and decodes the Booth digit
planes once; ``fir_apply(x, bank)`` then runs a fused code-level pipeline:
one float64 host quantize of the signal, one host->device transfer, a
single jitted sign-extend -> multiply-free kernel dispatch on the cached
digit planes, one device->host transfer, one float64 descale.  Nothing
else materializes in between.  (Quantize and descale are pinned to host
float64 by the bit-exactness contract: float32 rounding can differ by one
code, and all backends must agree bit for bit.)

All backends share quantization, the shift semantics (floor of each int
product), and the descale arithmetic, so for Booth-family specs their real
outputs are equal bit-for-bit.

`fir_apply_real` is the double-precision reference path; `fir_apply_fixed`
is the original host-only entry point, kept as a thin wrapper.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from scipy.signal import remez

from ..core.multipliers import MulSpec, mul
from ..kernels.booth_rows import booth_precode, resolve_form
from ..kernels.fir_kernel import (_DOT_WINDOW_BUDGET, fir_bbm_bank_precoded,
                                  min_safe_shift)
from .fixed_point import requant_scale

__all__ = ["design_lowpass", "fir_apply_real", "fir_apply",
           "fir_apply_fixed", "PrecodedBank", "FIR_DELAY", "BBM_KINDS"]

# paper testbed: passband edge 0.25*pi, guard (transition) band 0.1*pi
PASS_EDGE = 0.125      # in cycles/sample (omega / 2pi)
STOP_EDGE = 0.175
NUM_TAPS = 31          # order 30 -> integer group delay of 15
FIR_DELAY = (NUM_TAPS - 1) // 2

# specs the Pallas kernel implements natively: name -> closed-form kind
BBM_KINDS = {"booth": 0, "bbm0": 0, "bbm1": 1}


def design_lowpass(num_taps: int = NUM_TAPS,
                   stop_weight: float = 0.27) -> np.ndarray:
    """Parks-McClellan equiripple low-pass design for the paper's testbed.

    The paper does not state its remez error weighting; ``stop_weight`` is
    calibrated once so the double-precision testbed reproduces the paper's
    reported SNR_out of 25.7 dB (docs/filterbank.md §Testbed calibration —
    with equal weights the same 31-tap design gives 30.1 dB, i.e. our
    testbed is, if anything, conservative about the paper's headline
    numbers).
    """
    h = remez(num_taps, [0.0, PASS_EDGE, STOP_EDGE, 0.5], [1.0, 0.0],
              weight=[1.0, stop_weight])
    return h.astype(np.float64)


def fir_apply_real(x: np.ndarray, h: np.ndarray) -> np.ndarray:
    """Double-precision reference filtering (same alignment as fixed path).

    Accepts (N,)/(taps,) or batched (C, N)/(C, taps) like ``fir_apply``.
    """
    x2, h2, squeeze = _normalize(np.asarray(x, np.float64),
                                 np.asarray(h, np.float64))
    y = np.stack([np.convolve(x2[c], h2[c], mode="full")[: x2.shape[1]]
                  for c in range(x2.shape[0])])
    return y[0] if squeeze else y


def _normalize(x, h):
    """-> (x (C, N), h (C, taps), squeeze) with h broadcast per channel."""
    x = np.asarray(x)
    h = np.asarray(h)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    if h.ndim == 1:
        h = np.broadcast_to(h, (x.shape[0], h.shape[0]))
    if h.shape[0] != x.shape[0]:
        raise ValueError(f"{h.shape[0]} tap banks for {x.shape[0]} channels")
    return x, h, squeeze


@partial(jax.jit, static_argnames=("name", "wl", "param", "hbl", "shift",
                                   "taps"))
def _fir_accum_device(x_int, h_int, name, wl, param, hbl, shift, taps):
    """Fused per-tap shift-and-accumulate on device: O(C*N) live memory.

    One dispatch for the whole filter — the tap loop is unrolled at trace
    time, the delay line advances one sample per tap, and products
    accumulate in int32.  Exact only within the kernel envelope
    ``taps * 2^(2*wl - 1 - shift) < 2^31`` (the caller checks); inside it
    the int32 sum equals the float64 sum of the same integer products.
    """
    f = mul(MulSpec(name, wl, param, hbl))
    acc = jnp.zeros_like(x_int)
    xk = x_int
    for k in range(taps):
        prod = f(xk, h_int[:, k:k + 1])
        if shift:
            prod = prod >> shift
        acc = acc + prod
        if k + 1 < taps:
            # delay by one more sample; zero codes enter from the left
            xk = jnp.pad(xk, ((0, 0), (1, 0)))[:, :-1]
    return acc


def _window(x_int, taps: int):
    """(..., n, taps) sliding window of past samples: w[.., n, k] = x[.., n-k].

    Positions before the signal start hold zero codes (the delay line's
    initial state) — the multiplier still runs on them, like the silicon.
    Only the fallback paths materialize this (C, N, taps) array; the hot
    paths are per-tap shift-and-accumulate.
    """
    n = x_int.shape[-1]
    idx = jnp.arange(n)[:, None] - jnp.arange(taps)[None, :]
    return jnp.where(idx >= 0, x_int[..., jnp.clip(idx, 0)], 0)


@partial(jax.jit, static_argnames=("name", "wl", "param", "hbl"))
def _tap_products(x_int, h_int, name, wl, param, hbl):
    """(C, N, taps) per-tap products — windowed fallback path only."""
    spec = MulSpec(name, wl, param, hbl)
    w = _window(x_int, h_int.shape[-1])
    return mul(spec)(w, h_int[..., None, :])


def _delayed(xq: np.ndarray, k: int) -> np.ndarray:
    """x delayed by k samples with zero codes before the signal starts.

    Zero *initial state*, not suppressed products: before the signal
    starts the delay line holds zero codes and the multiplier still runs
    on them (Type1's zero-operand product is nonzero), exactly like the
    silicon pipeline and the Pallas kernel's zeroed halo.
    """
    if k == 0:
        return xq
    xd = np.zeros_like(xq)
    xd[:, k:] = xq[:, :-k]
    return xd


def _descale(acc, wl: int, shift: int, amp: np.ndarray) -> np.ndarray:
    """Shared accumulator -> real mapping (identical across backends)."""
    return acc * float(1 << shift) / requant_scale(wl) / amp


def _amp(x2: np.ndarray) -> np.ndarray:
    """Per-channel input scale so |x| < 1 with headroom; undone at output.

    Per channel (shape (C, 1)), not per batch, so a channel's quantized
    codes — and therefore its output bits — do not depend on what other
    signals happen to share the batch (serving determinism).
    """
    xmax = np.max(np.abs(x2), axis=-1, keepdims=True)
    return 1.0 / np.where(xmax > 0, 1.0001 * xmax, 1.0)


def _quantize64(x: np.ndarray, wl: int) -> np.ndarray:
    """Float64 host quantizer: real [-1,1) -> signed integers (int64).

    All backends quantize through this one function so that rounding is
    identical (float32 jnp rounding can differ by one code from float64).
    """
    scale = float(1 << (wl - 1))
    return np.clip(np.round(np.asarray(x, np.float64) * scale),
                   -scale, scale - 1).astype(np.int64)


def _codes32(q: np.ndarray, wl: int) -> np.ndarray:
    """Signed integers -> masked wl-bit int32 codes for the jax datapaths."""
    return (q & ((1 << wl) - 1)).astype(np.int32)


class PrecodedBank:
    """Tap banks quantized and Booth-precoded once, reused across calls.

    The decode phase of the Broken-Booth datapath (float64 quantization of
    the real taps + radix-4 digit extraction) depends only on the bank and
    the spec, not on the signals — so a serving engine or a long-lived
    filterbank builds it exactly once and every subsequent ``fir_apply``
    call skips straight to the multiply-free accumulate phase.

    h: (B, taps) real tap banks (or (taps,) for a single bank).
    ``take(idx)`` gathers per-request banks into a request-ordered view —
    a cheap index into the cached codes/planes, never a re-quantize or
    re-decode.  For Booth-family specs at wl <= 16 the digit planes
    (wl//2, B, taps) live on device, ready for either accumulate form:
    the rows kernel walks them as partial-product generators, and the dot
    form reads them twice — reconstructing the exact contraction operand
    (``booth_value``) and driving the low-bit correction
    (``booth_correction``), so they are also the dot form's correction
    planes and *both* backends now consume them.  ``precode=False``
    defers the digit decode until ``planes`` is first read; the default
    decodes eagerly so a serving engine pays the whole decode phase at
    construction, not on the first request.
    """

    def __init__(self, h, spec: MulSpec, *, precode: bool = True):
        h2 = np.atleast_2d(np.asarray(h, np.float64))
        if h2.ndim != 2:
            raise ValueError(f"tap banks must be (B, taps), got {h2.shape}")
        self.spec = spec
        self.h_real = h2
        self.hq = _quantize64(h2, spec.wl)          # int64 host codes
        self._planes = None                         # (mag, neg) digit planes
        if precode:
            self.planes                             # eager decode, cached

    @property
    def num_banks(self) -> int:
        return self.h_real.shape[0]

    @property
    def taps(self) -> int:
        return self.h_real.shape[1]

    @property
    def planes(self):
        """(mag, neg) digit planes of shape (wl//2, B, taps), device side.

        Decoded on first read and cached.  ``None`` for specs the Pallas
        kernel does not implement (non-Booth families, wl > 16) — those run
        on the host backend from ``hq``.
        """
        if self._planes is None and self.spec.name in BBM_KINDS \
                and self.spec.wl <= 16:
            codes = jnp.asarray(_codes32(self.hq, self.spec.wl))
            self._planes = booth_precode(codes, self.spec.wl)
        return self._planes

    def take(self, idx) -> "PrecodedBank":
        """Bank rows gathered per request: a view, never a re-decode."""
        idx = np.asarray(idx, np.int64)
        out = object.__new__(PrecodedBank)
        out.spec = self.spec
        out.h_real = self.h_real[idx]
        out.hq = self.hq[idx]
        out._planes = None if self._planes is None else tuple(
            p[:, jnp.asarray(idx), :] for p in self._planes)
        return out


def fir_apply(x: np.ndarray, h, spec: MulSpec | None = None, *,
              backend: str = "host", datapath: str = "full",
              shift: int | None = None, bc: int = 8,
              block: int = 512, form: str | None = None) -> np.ndarray:
    """Bit-exact fixed-point filtering with the given multiplier spec.

    x: signal(s), (N,) or (C, N); h: real taps, (taps,) or (C, taps) for
    per-channel banks, or a ``PrecodedBank`` whose rows match the channels
    (in which case ``spec`` defaults to the bank's spec).  Output has the
    shape of ``x``, aligned with ``fir_apply_real``.

    datapath="full"  — products accumulated at full precision (growing
                       accumulator, the Table-I-faithful setting).
    datapath="wlbit" — each product rounded back to Q(1, wl-1) and summed in
                       a saturating wl-bit accumulator: the low-power
                       wl-bit-adder datapath.  This is what produces the
                       paper's Fig. 8(a) cliff at small word lengths; with a
                       full-precision accumulator the word length barely
                       matters down to WL=8 (docs/filterbank.md §Testbed
                       calibration).  Host backend only.

    shift — per-product arithmetic right shift before accumulation (the MAC
    rescale).  ``None`` selects 0 when the int32 envelope allows it and the
    minimal safe value otherwise (wl = 16 at 31 taps needs shift = 5), so
    host and Pallas backends agree by default.

    form — Booth-family accumulate form, resolved at trace time and
    bit-identical either way: "rows" walks the wl/2 partial-product rows
    per tap (the silicon emulation), "dot" puts the dominant exact
    contraction on the matmul units and walks only the truncated rows
    (``kernels.booth_rows``), ``None`` auto-picks the dot form.  Applies
    to the Booth-family hot paths of both backends; the exact / wlbit /
    non-Booth paths ignore "rows" and reject an explicit "dot".
    """
    resolve_form(form)     # validate early; selection happens per path
    bank = h if isinstance(h, PrecodedBank) else None
    if bank is not None:
        if spec is not None and spec != bank.spec:
            raise ValueError(f"spec {spec} does not match the precoded "
                             f"bank's {bank.spec}")
        spec = bank.spec
        x2 = np.asarray(x)
        squeeze = x2.ndim == 1
        if squeeze:
            x2 = x2[None, :]
        if bank.num_banks == 1 and x2.shape[0] > 1:
            bank = bank.take(np.zeros(x2.shape[0], np.int64))
        if bank.num_banks != x2.shape[0]:
            raise ValueError(f"{bank.num_banks} precoded banks for "
                             f"{x2.shape[0]} channels")
        taps = bank.taps
    else:
        if spec is None:
            raise ValueError("spec is required unless h is a PrecodedBank")
        x2, h2, squeeze = _normalize(x, h)
        taps = h2.shape[1]
    wl = spec.wl
    if shift is None:
        # the rescale exists for the int32 kernel envelope; wlbit models its
        # own rounding and wl > 16 only runs on the exact int64 host path,
        # so neither needs (or should pay for) a default shift
        shift = 0 if (datapath == "wlbit" or wl > 16) \
            else min_safe_shift(taps, wl)
    amp = _amp(x2)
    xq = _quantize64(x2 * amp, wl)
    if bank is None:
        # one-shot bank: defer the decode to the first ``planes`` read —
        # the Booth-family dot path (either backend) triggers it once per
        # call, and the rows/exact/fallback host paths never pay it
        bank = PrecodedBank(h2, spec, precode=False)
    if backend in ("pallas", "pallas-interpret"):
        y = _apply_pallas(xq, bank, datapath=datapath, shift=shift,
                          amp=amp, bc=bc, block=block,
                          interpret=backend == "pallas-interpret",
                          form=form)
    elif backend == "host":
        y = _apply_host(xq, bank, datapath=datapath, shift=shift, amp=amp,
                        form=form)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return y[0] if squeeze else y


def _apply_pallas(xq, bank: PrecodedBank, *, datapath, shift, amp, bc,
                  block, interpret, form=None):
    from ..kernels.ops import fir_filterbank_precoded
    spec = bank.spec
    if spec.name not in BBM_KINDS:
        raise ValueError(f"backend='pallas' supports Booth-family specs "
                         f"{sorted(BBM_KINDS)}, not {spec.name!r}")
    if datapath != "full":
        raise ValueError("backend='pallas' implements the full-precision "
                         "accumulator datapath only")
    wl = spec.wl
    if wl > 16:
        raise ValueError("the int32 kernel datapath supports wl <= 16")
    vbl = 0 if spec.name == "booth" else spec.param
    # fused code-level pipeline: one transfer in, one jitted dispatch on the
    # cached digit planes (sign-extend + accumulate form), one out
    hmag, hneg = bank.planes
    out = fir_filterbank_precoded(jnp.asarray(_codes32(xq, wl)), hmag, hneg,
                                  wl=wl, vbl=vbl, kind=BBM_KINDS[spec.name],
                                  shift=shift, interpret=interpret, bc=bc,
                                  bt=block, form=form)
    return _descale(np.asarray(out, np.float64), wl, shift, amp)


def _apply_host(xq, bank: PrecodedBank, *, datapath, shift, amp, form=None):
    """Host datapath: exact contraction or per-tap accumulate, by form.

    Tap k contributes ``mul(x[n-k], h[k])``:

      * exact specs run a per-tap loop in int64 numpy (any wl; the
        float64 accumulator is exact while partial sums stay below 2^53),
      * Booth-family approximate specs inside the int32 envelope run a
        single fused device dispatch — the dot form (dense exact
        contraction + scaled truncated rows, from the bank's cached digit
        planes) by default; ``form="rows"`` pins the per-tap loop
        (``_fir_accum_device``).

    Everything else (wlbit's saturating per-product rounding, non-Booth
    multipliers, sub-envelope shifts) falls back to the windowed
    (C, N, taps) product array — off the hot path, semantics unchanged.
    """
    spec = bank.spec
    wl = spec.wl
    hq = bank.hq
    taps = hq.shape[1]
    if datapath not in ("full", "wlbit"):
        raise ValueError(f"unknown datapath {datapath!r}")
    if datapath == "wlbit" and shift:
        raise ValueError("datapath='wlbit' models its own product rounding; "
                         "use shift=0")
    lim = float(1 << (wl - 1))

    # Booth-family hot path on the full-precision datapath: a single fused
    # device dispatch on the bank's cached digit planes, inside the int32
    # envelope.  The dot form (dense exact contraction + scaled truncated
    # rows) is the default — this includes the *exact* "booth" spec
    # (vbl = 0, a pure dot); form="rows" pins the per-tap emulation.
    booth_hot = (datapath == "full" and spec.name in BBM_KINDS
                 and wl <= 16 and min_safe_shift(taps, wl) <= shift)
    if booth_hot:
        vbl = 0 if spec.name == "booth" else spec.param
        use_dot = resolve_form(form) == "dot"
        if use_dot and form is None and jax.default_backend() != "cpu" \
                and xq.size * taps > _DOT_WINDOW_BUDGET:
            # mirror the kernel's auto-form memory gate instead of
            # escalating None to an explicit "dot" (which would bypass
            # it); the fallback here is the host-native per-tap path
            use_dot = False
        if use_dot:
            xc = jnp.asarray(_codes32(xq, wl))
            hmag, hneg = bank.planes     # decoded once per bank, cached
            acc = np.asarray(fir_bbm_bank_precoded(
                xc, hmag, hneg, wl=wl, vbl=vbl, kind=BBM_KINDS[spec.name],
                shift=shift, form="dot"), np.float64)
            return _descale(acc, wl, shift, amp)
    elif form == "dot":
        raise ValueError("form='dot' needs a Booth-family spec on the "
                         "full-precision datapath inside the int32 "
                         "envelope")

    if spec.is_exact:
        # exact quantized path in int64 numpy: valid for any wl (the jax
        # closed forms are int32-bound to wl <= 16)
        acc = np.zeros(xq.shape, np.float64)
        for k in range(taps):
            prod = _delayed(xq, k) * hq[:, k:k + 1]
            if shift:
                prod = prod >> shift        # arithmetic shift == floor
            if datapath == "full":
                acc += prod.astype(np.float64)
            else:
                p_wl = np.clip(np.round(prod / lim), -lim, lim - 1)
                acc = np.clip(acc + p_wl, -lim, lim - 1)
        return _descale(acc, wl, shift, amp) if datapath == "full" \
            else acc / lim / amp

    if wl > 16:
        raise ValueError("approximate fixed-point path supports wl <= 16 "
                         "(int32-exact); the paper's operating point is 16")
    xc = jnp.asarray(_codes32(xq, wl))
    hc = jnp.asarray(_codes32(hq, wl))
    if booth_hot:
        acc = np.asarray(_fir_accum_device(xc, hc, spec.name, wl, spec.param,
                                           spec.hbl, shift, taps), np.float64)
        return _descale(acc, wl, shift, amp)

    # windowed fallback: per-tap products materialized, then reduced
    prod = np.asarray(_tap_products(xc, hc, spec.name, wl, spec.param,
                                    spec.hbl), np.int64)
    if shift:
        prod = prod >> shift
    if datapath == "full":
        return _descale(prod.astype(np.float64).sum(axis=-1), wl, shift, amp)
    # round each 2wl-bit product back to Q(1, wl-1), saturate, then sum in a
    # saturating wl-bit accumulator (left-to-right tap order)
    p_wl = np.clip(np.round(prod.astype(np.float64) / lim), -lim, lim - 1)
    acc = np.zeros(prod.shape[:-1])
    for k in range(p_wl.shape[-1]):
        acc = np.clip(acc + p_wl[..., k], -lim, lim - 1)
    return acc / lim / amp


def fir_apply_fixed(x: np.ndarray, h: np.ndarray, spec: MulSpec,
                    datapath: str = "full") -> np.ndarray:
    """Original host-only entry point (kept for callers and tests)."""
    return fir_apply(x, h, spec, backend="host", datapath=datapath, shift=0)
