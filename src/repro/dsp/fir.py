"""Fixed-point FIR filter built on approximate multipliers (paper §III.C).

The paper's application: a 30-tap-order Parks--McClellan low-pass filter
whose tap multipliers are replaced by Broken-Booth multipliers.  We model
the datapath bit-exactly:

  * input samples and coefficients quantized to Q(1, wl-1),
  * every tap product computed by the selected approximate multiplier
    (`core.multipliers`), vectorized over (samples x taps),
  * products accumulated at full precision (the 2*wl + log2(taps) bit
    accumulator every sane FIR datapath carries; numerically exact here via
    float64 on the host — int products are < 2^31 so the sum of 31 of them is
    exact in float64's 53-bit mantissa).

`fir_apply_real` is the double-precision reference path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from scipy.signal import remez

from ..core.multipliers import MulSpec, mul
from .fixed_point import quantize, requant_scale

__all__ = ["design_lowpass", "fir_apply_real", "fir_apply_fixed", "FIR_DELAY"]

# paper testbed: passband edge 0.25*pi, guard (transition) band 0.1*pi
PASS_EDGE = 0.125      # in cycles/sample (omega / 2pi)
STOP_EDGE = 0.175
NUM_TAPS = 31          # order 30 -> integer group delay of 15
FIR_DELAY = (NUM_TAPS - 1) // 2


def design_lowpass(num_taps: int = NUM_TAPS,
                   stop_weight: float = 0.27) -> np.ndarray:
    """Parks-McClellan equiripple low-pass design for the paper's testbed.

    The paper does not state its remez error weighting; ``stop_weight`` is
    calibrated once so the double-precision testbed reproduces the paper's
    reported SNR_out of 25.7 dB (see EXPERIMENTS.md — with equal weights the
    same 31-tap design gives 30.1 dB, i.e. our testbed is, if anything,
    conservative about the paper's headline numbers).
    """
    h = remez(num_taps, [0.0, PASS_EDGE, STOP_EDGE, 0.5], [1.0, 0.0],
              weight=[1.0, stop_weight])
    return h.astype(np.float64)


def fir_apply_real(x: np.ndarray, h: np.ndarray) -> np.ndarray:
    """Double-precision reference filtering (same alignment as fixed path)."""
    return np.convolve(x, h, mode="full")[: len(x)]


def _window(x_int, taps: int):
    """(n, taps) sliding window of past samples: w[n, k] = x[n-k]."""
    n = x_int.shape[0]
    idx = jnp.arange(n)[:, None] - jnp.arange(taps)[None, :]
    valid = idx >= 0
    return jnp.where(valid, x_int[jnp.clip(idx, 0)], 0), valid


@partial(jax.jit, static_argnames=("name", "wl", "param", "hbl"))
def _tap_products(x_int, h_int, name, wl, param, hbl):
    spec = MulSpec(name, wl, param, hbl)
    w, valid = _window(x_int, h_int.shape[0])
    prod = mul(spec)(w, h_int[None, :])
    return jnp.where(valid, prod, 0)


def fir_apply_fixed(x: np.ndarray, h: np.ndarray, spec: MulSpec,
                    datapath: str = "full") -> np.ndarray:
    """Bit-exact fixed-point filtering with the given multiplier spec.

    datapath="full"  — products accumulated at full precision (growing
                       accumulator, the Table-I-faithful setting).
    datapath="wlbit" — each product rounded back to Q(1, wl-1) and summed in
                       a saturating wl-bit accumulator: the low-power
                       wl-bit-adder datapath.  This is what produces the
                       paper's Fig. 8(a) cliff at small word lengths; with a
                       full-precision accumulator the word length barely
                       matters down to WL=8 (documented in EXPERIMENTS.md).

    Returns the real-valued output (descaled), aligned with fir_apply_real.
    """
    wl = spec.wl
    # scale so |x| < 1 with a little headroom; undo at the output.
    xmax = float(np.max(np.abs(x)))
    amp = 1.0 / (1.0001 * xmax) if xmax > 0 else 1.0
    if spec.is_exact:
        # exact quantized path in int64 numpy: valid for any wl (the jax
        # closed forms are int32-bound to wl <= 16)
        scale = float(1 << (wl - 1))
        xq = np.clip(np.round(x * amp * scale), -scale, scale - 1)
        hq = np.clip(np.round(h * scale), -scale, scale - 1)
        prod = _window_np(xq, len(hq))[0] * hq[None, :]
    else:
        if wl > 16:
            raise ValueError("approximate fixed-point path supports wl <= 16 "
                             "(int32-exact); the paper's operating point is 16")
        x_int = quantize(jnp.asarray(x * amp), wl)
        h_int = quantize(jnp.asarray(h), wl)
        prod = np.asarray(
            _tap_products(x_int, h_int, spec.name, wl, spec.param, spec.hbl),
            dtype=np.float64)
    if datapath == "full":
        acc = prod.sum(axis=1)
        return acc / requant_scale(wl) / amp
    if datapath != "wlbit":
        raise ValueError(f"unknown datapath {datapath!r}")
    # round each 2wl-bit product back to Q(1, wl-1), saturate, then sum in a
    # saturating wl-bit accumulator (left-to-right tap order)
    lim = float(1 << (wl - 1))
    p_wl = np.clip(np.round(prod / lim), -lim, lim - 1)
    acc = np.zeros(prod.shape[0])
    for k in range(p_wl.shape[1]):
        acc = np.clip(acc + p_wl[:, k], -lim, lim - 1)
    return acc / lim / amp


def _window_np(x: np.ndarray, taps: int):
    n = len(x)
    idx = np.arange(n)[:, None] - np.arange(taps)[None, :]
    valid = idx >= 0
    return np.where(valid, x[np.clip(idx, 0, None)], 0.0), valid
