"""SNR testbed of Fig. 7 (Shim & Shanbhag, paper ref [12]).

Input  x[n] = d1[n] + d2[n] + d3[n] + eta[n]:
  d1 — desired signal, passband      [0,        0.25*pi]
  d2 — on the transition band        [0.35*pi,  0.60*pi]
  d3 — in the stopband               [0.70*pi,  0.95*pi]
  each d_i: unit-power white Gaussian noise ideally band-limited to a
  0.25*pi-wide band, with 0.1*pi guard bands between them;
  eta — white Gaussian noise with -30 dB power spectral density.

    SNR_out = 10 log10( var(d1) / E|d1 - y|^2 )   (y: filter output)
    SNR_in  = 10 log10( var(d1) / E|d1 - x|^2 )

The filter's integer group delay is compensated before differencing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.multipliers import MulSpec
from .fir import FIR_DELAY, design_lowpass, fir_apply, fir_apply_real

__all__ = ["TestSignals", "make_signals", "make_filterbank_signals",
           "snr_db", "run_filter_case", "run_filterbank_case"]

BANDS = [(0.0, 0.125), (0.175, 0.30), (0.35, 0.475)]  # cycles/sample
NOISE_PSD_DB = -30.0


@dataclasses.dataclass
class TestSignals:
    x: np.ndarray        # filter input
    d1: np.ndarray       # desired signal
    n: int


def _bandlimited_noise(rng, n: int, lo: float, hi: float) -> np.ndarray:
    """Unit-power Gaussian noise ideally band-limited to [lo, hi] c/s."""
    spec = np.fft.rfft(rng.standard_normal(n))
    f = np.fft.rfftfreq(n)
    mask = (f >= lo) & (f <= hi)
    spec[~mask] = 0.0
    sig = np.fft.irfft(spec, n)
    return sig / sig.std()


def make_signals(n: int = 1 << 14, seed: int = 0) -> TestSignals:
    rng = np.random.default_rng(seed)
    d = [_bandlimited_noise(rng, n, lo, hi) for lo, hi in BANDS]
    eta_power = 10.0 ** (NOISE_PSD_DB / 10.0)
    eta = rng.standard_normal(n) * np.sqrt(eta_power)
    x = d[0] + d[1] + d[2] + eta
    return TestSignals(x=x, d1=d[0], n=n)


def snr_db(d1: np.ndarray, y: np.ndarray, delay: int = 0) -> float:
    """10 log10(var(d1) / E|d1 - y|^2) with delay compensation."""
    if delay:
        d1a = d1[: len(d1) - delay]
        ya = y[delay:]
    else:
        d1a, ya = d1, y
    # trim filter warm-up
    d1a, ya = d1a[64:], ya[64:]
    err = d1a - ya
    return 10.0 * np.log10(np.var(d1a) / np.mean(err * err))


def run_filter_case(spec: MulSpec | None, signals: TestSignals | None = None,
                    h: np.ndarray | None = None, *,
                    backend: str = "host") -> float:
    """SNR_out for one filter realization.

    spec=None -> double-precision filter; otherwise the fixed-point filter
    with the given approximate-multiplier spec, dispatched through the
    unified ``fir_apply`` datapath (host or Pallas backend).
    """
    sig = signals or make_signals()
    hh = design_lowpass() if h is None else h
    if spec is None:
        y = fir_apply_real(sig.x, hh)
    else:
        # host keeps the seed's exact full-precision accumulation; the
        # int32 kernel backends need the minimal safe rescale at wl = 16
        shift = 0 if backend == "host" else None
        y = fir_apply(sig.x, hh, spec, backend=backend, shift=shift)
    return snr_db(sig.d1, y, FIR_DELAY)


def make_filterbank_signals(channels: int, n: int = 1 << 13,
                            seed: int = 0) -> list[TestSignals]:
    """Independent testbed realizations, one per filterbank channel."""
    return [make_signals(n=n, seed=seed + c) for c in range(channels)]


def run_filterbank_case(spec: MulSpec | None, channels: int = 4, *,
                        signals: list[TestSignals] | None = None,
                        h_banks: np.ndarray | None = None,
                        backend: str = "host",
                        n: int = 1 << 13) -> list[float]:
    """Per-channel SNR_out for a multi-channel filterbank run.

    Channels alternate between two tap banks by default (the paper's
    design plus a slightly re-weighted variant), exercising the
    per-channel-bank path end to end.  Returns ``channels`` SNR values.
    """
    sigs = signals or make_filterbank_signals(channels, n=n)
    if h_banks is None:
        h_banks = np.stack([design_lowpass(), design_lowpass(
            stop_weight=0.5)])
    x = np.stack([s.x for s in sigs])
    h = h_banks[np.arange(channels) % len(h_banks)]
    if spec is None:
        y = fir_apply_real(x, h)
    else:
        y = fir_apply(x, h, spec, backend=backend)
    return [snr_db(s.d1, y[c], FIR_DELAY) for c, s in enumerate(sigs)]
