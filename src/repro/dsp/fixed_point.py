"""Fixed-point quantization helpers (Q-format, two's complement).

Values live in [-1, 1) as Q(1, wl-1): q = round(x * 2^(wl-1)) clipped to the
signed wl-bit range.  Integers are carried in int32 masked to wl bits so they
feed the core multipliers directly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["quantize", "dequantize", "requant_scale"]


def quantize(x, wl: int):
    """Real [-1,1) -> signed wl-bit integer code (int32, masked to wl bits)."""
    scale = float(1 << (wl - 1))
    q = jnp.clip(jnp.round(x * scale), -scale, scale - 1).astype(jnp.int32)
    return q & ((1 << wl) - 1)


def dequantize(q_signed, wl: int):
    """Signed integer code -> real."""
    return q_signed.astype(jnp.float32) / float(1 << (wl - 1))


def requant_scale(wl: int) -> float:
    """Scale of a full-precision product of two Q(1, wl-1) values."""
    return float(1 << (2 * (wl - 1)))
