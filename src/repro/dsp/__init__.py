"""Fixed-point DSP substrate: FIR filter + SNR testbed (paper §III.C)."""
from .fixed_point import dequantize, quantize, requant_scale
from .fir import FIR_DELAY, design_lowpass, fir_apply_fixed, fir_apply_real
from .testbed import TestSignals, make_signals, run_filter_case, snr_db

__all__ = [
    "dequantize", "quantize", "requant_scale",
    "FIR_DELAY", "design_lowpass", "fir_apply_fixed", "fir_apply_real",
    "TestSignals", "make_signals", "run_filter_case", "snr_db",
]
