"""Fixed-point DSP substrate: FIR filterbank + SNR testbed (paper §III.C)."""
from .fixed_point import dequantize, quantize, requant_scale
from .fir import (BBM_KINDS, FIR_DELAY, PrecodedBank, design_lowpass,
                  fir_apply, fir_apply_fixed, fir_apply_real)
from .testbed import (TestSignals, make_filterbank_signals, make_signals,
                      run_filter_case, run_filterbank_case, snr_db)

__all__ = [
    "dequantize", "quantize", "requant_scale",
    "BBM_KINDS", "FIR_DELAY", "PrecodedBank", "design_lowpass", "fir_apply",
    "fir_apply_fixed", "fir_apply_real",
    "TestSignals", "make_filterbank_signals", "make_signals",
    "run_filter_case", "run_filterbank_case", "snr_db",
]
