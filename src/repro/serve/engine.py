"""Serving engine: prefill + decode steps and a slot-based batch scheduler.

``make_serve_fns`` builds the two jitted entry points the dry-run lowers:

  prefill_fn(params, tokens, caches)        -> (logits_last, caches)
  decode_fn(params, tokens_1, caches, pos)  -> (logits, caches)

The KV caches are sharded by logical rules (batch over data, kv_heads over
model, MLA latent over seq on model — see parallel/logical.py), and decode
donates the cache buffers so each step updates in place.

``Scheduler`` is a minimal continuous-batching loop for the serving example:
fixed slot count, requests enter free slots, finished slots are recycled.

``FilterbankEngine`` is the batched request path for the paper's own
workload: FIR filtering requests accumulate into channel slots and are
served by a single multi-channel Broken-Booth filterbank dispatch
(``dsp.fir_apply``), one kernel call per flush instead of one per signal.
The tap banks are fixed for the engine's lifetime, so their quantization
and Booth recode happen exactly once, at construction, via
``dsp.PrecodedBank``; every flush gathers the cached digit planes by
request index instead of re-deriving them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import ModelRuntime, init_cache, lm_amm_planes, lm_apply
from ..parallel.logical import (RULES, RULES_MULTIPOD, batch_pspec,
                                is_multipod, spec_to_pspec, tree_shardings)

__all__ = ["cache_logical_axes", "make_serve_fns", "Scheduler",
           "FilterRequest", "FilterbankEngine"]


def cache_logical_axes(cfg: ArchConfig) -> Dict[str, Any]:
    """Logical axes for every cache leaf (mirrors models.init_cache)."""
    if cfg.family in ("dense", "vlm", "audio"):
        kvax = ("layers", "batch", "seq", "kv_heads", "head_dim")
        c = {"k": kvax, "v": kvax}
        if cfg.is_encoder_decoder:
            c["xk"] = kvax
            c["xv"] = kvax
        return c
    if cfg.family == "moe":
        if cfg.use_mla:
            # no head axis to shard: shard the *sequence* over model
            return {"latent": ("layers", "batch", "seq_model", "kv_latent")}
        kvax = ("layers", "batch", "seq", "kv_heads", "head_dim")
        return {"k": kvax, "v": kvax}
    if cfg.family == "ssm":
        return {"ssm": ("layers", "batch", "ssm_heads", "head_dim",
                        "ssm_state"),
                "conv": ("layers", "batch", "conv", "ssm_inner")}
    if cfg.family == "hybrid":
        return {"ssm": ("layers", None, "batch", "ssm_heads", "head_dim",
                        "ssm_state"),
                "conv": ("layers", None, "batch", "conv", "ssm_inner"),
                "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
                "v": ("layers", "batch", "seq", "kv_heads", "head_dim")}
    raise ValueError(cfg.family)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int):
    from ..models import init_cache
    rules = dict(RULES_MULTIPOD if is_multipod(mesh) else RULES)
    rules["seq_model"] = "model"
    structs = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return jax.tree.map(
        lambda axes, st: NamedSharding(
            mesh, spec_to_pspec(axes, rules, tuple(st.shape), mesh)),
        cache_logical_axes(cfg), structs,
        is_leaf=lambda x: isinstance(x, tuple))


def make_serve_fns(cfg: ArchConfig, rt: ModelRuntime, mesh: Mesh, *,
                   batch: int, max_len: int, amm_planes=None):
    """(prefill_fn, decode_fn) jitted with explicit shardings.

    amm_planes: optional ``lm_amm_planes`` cache for the bitexact
    approximate-matmul datapath — serving weights are fixed, so the
    weight-side quantize + Booth decode happens once here instead of in
    every prefill/decode step (the closures capture the concrete planes).
    Attention routing (``AmmConfig.apply_to`` "attn"/"all") needs no
    wiring beyond ``rt``: the score/value products are activation x
    activation, quantized per step inside ``lm_apply`` — there is no
    weight side for a plane cache to hoist (docs/attention.md).
    """
    from ..models import lm_logical_axes, lm_table
    p_rules = RULES_MULTIPOD if is_multipod(mesh) else RULES
    p_sh = tree_shardings(lm_logical_axes(cfg), mesh, p_rules,
                          shapes_tree=lm_table(cfg))
    c_sh = cache_shardings(cfg, mesh, batch, max_len)
    b_sh = NamedSharding(mesh, batch_pspec(mesh, batch))
    scalar = NamedSharding(mesh, P())

    def prefill(params, tokens, caches, encoder_embeds=None):
        logits, _, new_caches = lm_apply(
            params, cfg, rt, tokens, mode="decode", caches=caches,
            pos=jnp.int32(0), encoder_embeds=encoder_embeds,
            amm_planes=amm_planes)
        return logits[:, -1], new_caches

    def decode(params, tokens, caches, pos, encoder_embeds=None):
        logits, _, new_caches = lm_apply(
            params, cfg, rt, tokens, mode="decode", caches=caches, pos=pos,
            encoder_embeds=encoder_embeds, amm_planes=amm_planes)
        return logits[:, -1], new_caches

    enc_sh = (b_sh,) if cfg.is_encoder_decoder else ()
    prefill_j = jax.jit(prefill, in_shardings=(p_sh, b_sh, c_sh) + enc_sh,
                        out_shardings=(b_sh, c_sh))
    decode_j = jax.jit(decode,
                       in_shardings=(p_sh, b_sh, c_sh, scalar) + enc_sh,
                       out_shardings=(b_sh, c_sh),
                       donate_argnums=(2,))
    return prefill_j, decode_j


@dataclasses.dataclass
class FilterRequest:
    rid: int
    signal: np.ndarray            # 1-D real samples
    bank: int = 0                 # which tap bank filters this request


class FilterbankEngine:
    """Batched FIR serving: N pending requests -> one filterbank dispatch.

    Tap banks are designed/passed once at construction; each request names
    the bank that should filter it.  Construction also quantizes and
    Booth-precodes the banks exactly once (``dsp.PrecodedBank``) — the
    decode phase of the Broken-Booth datapath never runs again for the
    engine's lifetime, and the cached digit planes double as the dot
    form's correction planes, so every flush picks the exact-dot +
    correction lowering automatically (``form=None``; pass ``form="rows"``
    to pin the row emulation).  ``flush`` pads the pending signals to a
    common length, stacks them into a (C, N) batch, gathers the
    per-request banks out of the precoded cache (an index, not a
    re-quantize/re-recode), runs the whole batch through ``dsp.fir_apply``
    (host or Pallas backend) in a single call, and returns each request's
    output trimmed back to its own length.
    """

    def __init__(self, h_banks: np.ndarray, spec, *, backend: str = "host",
                 max_channels: int = 64, block: int = 512,
                 form: Optional[str] = None):
        from ..dsp.fir import BBM_KINDS, PrecodedBank, fir_apply
        from ..kernels.booth_rows import resolve_form
        h_banks = np.atleast_2d(np.asarray(h_banks, np.float64))
        self.h_banks = h_banks
        self.spec = spec
        self.backend = backend
        self.max_channels = max_channels
        self.block = block
        resolve_form(form)    # fail fast: flush() dispatches before it
        if form == "dot" and (spec.name not in BBM_KINDS or spec.wl > 16):
            # reject at construction what every flush would reject — the
            # dispatch-before-dequeue contract would otherwise wedge the
            # queue permanently
            raise ValueError(f"form='dot' needs a Booth-family spec at "
                             f"wl <= 16, not {spec}")
        self.form = form          # "rows" | "dot" | None (auto: dot)
        self._apply = fir_apply
        # decode phase hoisted out of the serving hot loop: built once here,
        # reused (gathered by request index) across every flush.  Both
        # backends read the digit planes now — they double as the dot
        # form's correction planes — so always decode eagerly; the bank
        # itself skips the decode for specs no kernel form implements.
        self.bank = PrecodedBank(h_banks, spec)
        self._pending: List[FilterRequest] = []
        self._next_rid = 0

    def submit(self, signal: np.ndarray, bank: int = 0) -> int:
        """Queue one signal; returns its request id."""
        if not 0 <= bank < len(self.h_banks):
            raise ValueError(f"unknown tap bank {bank}")
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(FilterRequest(rid, np.asarray(signal), bank))
        return rid

    def flush(self) -> Dict[int, np.ndarray]:
        """Serve every pending request; returns {rid: filtered signal}."""
        results: Dict[int, np.ndarray] = {}
        while self._pending:
            batch = self._pending[: self.max_channels]
            n = max(len(r.signal) for r in batch)
            x = np.zeros((len(batch), n))
            for c, r in enumerate(batch):
                x[c, : len(r.signal)] = r.signal
            h = self.bank.take([r.bank for r in batch])
            # dispatch before dequeue: a raising backend leaves the batch
            # queued so a later flush can still serve it
            y = self._apply(x, h, self.spec, backend=self.backend,
                            block=self.block, form=self.form)
            self._pending = self._pending[self.max_channels:]
            for c, r in enumerate(batch):
                results[r.rid] = y[c, : len(r.signal)]
        return results


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Scheduler:
    """Slot-based continuous batching over the jitted decode step."""

    def __init__(self, cfg: ArchConfig, rt: ModelRuntime, params,
                 batch_slots: int, max_len: int, decode_fn=None):
        self.cfg, self.rt, self.params = cfg, rt, params
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.max_len = max_len
        self.caches = init_cache(cfg, batch_slots, max_len)
        self.queue: List[Request] = []
        self.decode_fn = decode_fn
        # serving weights are fixed: hoist the bitexact datapath's weight
        # quantize + Booth digit decode out of the decode loop (None for
        # amm modes with nothing to cache).  A supplied decode_fn owns its
        # own closure (launch/serve.py bakes the planes into the jitted
        # fn) — only the fallback path needs a cache here, so don't build
        # and hold a second copy of the (wl//2, K, N) planes.
        self.amm_planes = (lm_amm_planes(cfg, rt.amm, params)
                           if decode_fn is None else None)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.pos[i] = 0
                req._pending = list(req.prompt)     # tokens still to feed

    def step(self) -> int:
        """One decode step over all live slots; returns #live requests."""
        self._admit()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            toks[i, 0] = (s._pending.pop(0) if s._pending
                          else (s.out[-1] if s.out else 0))
        pos = int(self.pos[live[0]])   # homogeneous-pos simplification
        def _default_fn(p, t, c, q):
            logits, _, new_c = lm_apply(
                p, self.cfg, self.rt, jnp.asarray(t), mode="decode",
                caches=c, pos=jnp.int32(q), amm_planes=self.amm_planes)
            return logits[:, -1], new_c

        fn = self.decode_fn or _default_fn
        logits, self.caches = fn(self.params, jnp.asarray(toks),
                                 self.caches, jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in live:
            s = self.slots[i]
            self.pos[i] += 1
            if not s._pending:          # past the prompt: emit
                s.out.append(int(nxt[i]))
                if len(s.out) >= s.max_new or self.pos[i] >= self.max_len - 1:
                    s.done = True
                    self.slots[i] = None
        return len(live)
