"""Serving engine: prefill + decode steps and a slot-based batch scheduler.

``make_serve_fns`` builds the two jitted entry points the dry-run lowers:

  prefill_fn(params, tokens, caches)        -> (logits_last, caches)
  decode_fn(params, tokens_1, caches, pos)  -> (logits, caches)

The KV caches are sharded by logical rules (batch over data, kv_heads over
model, MLA latent over seq on model — see parallel/logical.py), and decode
donates the cache buffers so each step updates in place.

``Scheduler`` serves LM requests from a fixed pool of batch slots in two
modes.  The legacy flush mode (``continuous=False``) admits only into an
idle batch and walks every resident in lockstep — the homogeneous-position
simplification.  Continuous mode (``continuous=True``) admits per step
into any free slot, prefills the prompt as one batch-1 dispatch against
the slot's cache slice (so a long prompt never stalls resident decodes),
decodes all residents with per-slot positions, and evicts on completion
or failure.  With ``kv_codes=True`` the cache holds wl-bit int codes plus
per-block scales (``serve.kv_cache``): token representations are frozen
at write time, so each request's token stream is bitwise-identical to its
solo run — the batch-invariance contract tests/test_serve_continuous.py
pins (the requantize-per-call float cache cannot make it under staggered
admission).

``FilterbankEngine`` is the batched request path for the paper's own
workload: FIR filtering requests accumulate into channel slots and are
served by a single multi-channel Broken-Booth filterbank dispatch
(``dsp.fir_apply``), one kernel call per flush instead of one per signal.
The tap banks are fixed for the engine's lifetime, so their quantization
and Booth recode happen exactly once, at construction, via
``dsp.PrecodedBank``; every flush gathers the cached digit planes by
request index instead of re-deriving them.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..core.guards import GuardConfig, finite_rows
from ..models import ModelRuntime, init_cache, lm_amm_planes, lm_apply
from ..parallel.logical import (RULES, RULES_MULTIPOD, batch_pspec,
                                is_multipod, spec_to_pspec, tree_shardings)
from .kv_cache import (KV_BLOCK, batch_axis_tree, code_cache_logical_axes,
                       init_code_cache, reset_slot, slot_put, slot_take)

__all__ = ["cache_logical_axes", "make_serve_fns", "Request", "Scheduler",
           "FilterRequest", "FilterbankEngine"]


def cache_logical_axes(cfg: ArchConfig, *,
                       kv_codes: bool = False) -> Dict[str, Any]:
    """Logical axes for every cache leaf (mirrors models.init_cache).

    kv_codes=True mirrors ``serve.kv_cache.init_code_cache`` instead.
    """
    if kv_codes:
        return code_cache_logical_axes(cfg)
    if cfg.family in ("dense", "vlm", "audio"):
        kvax = ("layers", "batch", "seq", "kv_heads", "head_dim")
        c = {"k": kvax, "v": kvax}
        if cfg.is_encoder_decoder:
            c["xk"] = kvax
            c["xv"] = kvax
        return c
    if cfg.family == "moe":
        if cfg.use_mla:
            # no head axis to shard: shard the *sequence* over model
            return {"latent": ("layers", "batch", "seq_model", "kv_latent")}
        kvax = ("layers", "batch", "seq", "kv_heads", "head_dim")
        return {"k": kvax, "v": kvax}
    if cfg.family == "ssm":
        return {"ssm": ("layers", "batch", "ssm_heads", "head_dim",
                        "ssm_state"),
                "conv": ("layers", "batch", "conv", "ssm_inner")}
    if cfg.family == "hybrid":
        return {"ssm": ("layers", None, "batch", "ssm_heads", "head_dim",
                        "ssm_state"),
                "conv": ("layers", None, "batch", "conv", "ssm_inner"),
                "k": ("layers", "batch", "seq", "kv_heads", "head_dim"),
                "v": ("layers", "batch", "seq", "kv_heads", "head_dim")}
    raise ValueError(cfg.family)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int,
                    *, kv_codes: bool = False, kv_wl: int = 16,
                    kv_block: int = KV_BLOCK):
    from ..models import init_cache
    rules = dict(RULES_MULTIPOD if is_multipod(mesh) else RULES)
    rules["seq_model"] = "model"
    if kv_codes:
        structs = jax.eval_shape(lambda: init_code_cache(
            cfg, batch, max_len, wl=kv_wl, block=kv_block))
    else:
        structs = jax.eval_shape(lambda: init_cache(cfg, batch, max_len))
    return jax.tree.map(
        lambda axes, st: NamedSharding(
            mesh, spec_to_pspec(axes, rules, tuple(st.shape), mesh)),
        cache_logical_axes(cfg, kv_codes=kv_codes), structs,
        is_leaf=lambda x: isinstance(x, tuple))


def make_serve_fns(cfg: ArchConfig, rt: ModelRuntime, mesh: Mesh, *,
                   batch: int, max_len: int, amm_planes=None,
                   kv_codes: bool = False, kv_block: int = KV_BLOCK):
    """(prefill_fn, decode_fn) jitted with explicit shardings.

    amm_planes: optional ``lm_amm_planes`` cache for the bitexact
    approximate-matmul datapath — serving weights are fixed, so the
    weight-side quantize + Booth decode happens once here instead of in
    every prefill/decode step (the closures capture the concrete planes).
    Attention routing (``AmmConfig.apply_to`` "attn"/"all") needs no
    wiring beyond ``rt``: the score/value products are activation x
    activation, quantized per step inside ``lm_apply`` — there is no
    weight side for a plane cache to hoist (docs/attention.md).

    kv_codes=True shards the int-code cache layout instead (requires an
    active Booth-family bitexact attention lowering on ``rt``).  ``pos``
    accepts a scalar or a (B,) per-slot vector either way (the vector is
    replicated — it is B int32s).  Continuous-mode prefill calls the
    prefill fn on batch-1 slot slices, retracing once per distinct prompt
    length (NamedShardings are shape-agnostic, so the same jitted fn
    serves both the warmup full-batch prefill and the slot slices).
    """
    from ..models import lm_logical_axes, lm_table
    if kv_codes and rt.amm.attn_lowering is None:
        raise ValueError("kv_codes serving requires an active Booth-family "
                         "bitexact amm attention lowering")
    p_rules = RULES_MULTIPOD if is_multipod(mesh) else RULES
    p_sh = tree_shardings(lm_logical_axes(cfg), mesh, p_rules,
                          shapes_tree=lm_table(cfg))
    c_sh = cache_shardings(
        cfg, mesh, batch, max_len, kv_codes=kv_codes,
        kv_wl=(rt.amm.attn_lowering[0] if kv_codes else 16),
        kv_block=kv_block)
    b_sh = NamedSharding(mesh, batch_pspec(mesh, batch))
    scalar = NamedSharding(mesh, P())

    def prefill(params, tokens, caches, encoder_embeds=None):
        logits, _, new_caches = lm_apply(
            params, cfg, rt, tokens, mode="decode", caches=caches,
            pos=jnp.int32(0), encoder_embeds=encoder_embeds,
            amm_planes=amm_planes)
        return logits[:, -1], new_caches

    def decode(params, tokens, caches, pos, encoder_embeds=None):
        logits, _, new_caches = lm_apply(
            params, cfg, rt, tokens, mode="decode", caches=caches, pos=pos,
            encoder_embeds=encoder_embeds, amm_planes=amm_planes)
        return logits[:, -1], new_caches

    enc_sh = (b_sh,) if cfg.is_encoder_decoder else ()
    prefill_j = jax.jit(prefill, in_shardings=(p_sh, b_sh, c_sh) + enc_sh,
                        out_shardings=(b_sh, c_sh))
    decode_j = jax.jit(decode,
                       in_shardings=(p_sh, b_sh, c_sh, scalar) + enc_sh,
                       out_shardings=(b_sh, c_sh),
                       donate_argnums=(2,))
    return prefill_j, decode_j


@dataclasses.dataclass
class FilterRequest:
    rid: int
    signal: np.ndarray            # 1-D real samples
    bank: int = 0                 # which tap bank filters this request


class FilterbankEngine:
    """Batched FIR serving: N pending requests -> one filterbank dispatch.

    Tap banks are designed/passed once at construction; each request names
    the bank that should filter it.  Construction also quantizes and
    Booth-precodes the banks exactly once (``dsp.PrecodedBank``) — the
    decode phase of the Broken-Booth datapath never runs again for the
    engine's lifetime, and the cached digit planes double as the dot
    form's correction planes, so every flush picks the exact-dot +
    correction lowering automatically (``form=None``; pass ``form="rows"``
    to pin the row emulation).  ``flush`` pads the pending signals to a
    common length, stacks them into a (C, N) batch, gathers the
    per-request banks out of the precoded cache (an index, not a
    re-quantize/re-recode), runs the whole batch through ``dsp.fir_apply``
    (host or Pallas backend) in a single call, and returns each request's
    output trimmed back to its own length.
    """

    def __init__(self, h_banks: np.ndarray, spec, *, backend: str = "host",
                 max_channels: int = 64, block: int = 512,
                 form: Optional[str] = None,
                 guard: Optional[GuardConfig] = None, max_retries: int = 1):
        from ..dsp.fir import BBM_KINDS, PrecodedBank, fir_apply
        from ..kernels.booth_rows import resolve_form
        h_banks = np.atleast_2d(np.asarray(h_banks, np.float64))
        self.h_banks = h_banks
        self.spec = spec
        self.backend = backend
        self.max_channels = max_channels
        self.block = block
        resolve_form(form)    # fail fast: flush() dispatches before it
        if form == "dot" and (spec.name not in BBM_KINDS or spec.wl > 16):
            # reject at construction what every flush would reject — the
            # whole queue would otherwise drain straight into quarantine
            raise ValueError(f"form='dot' needs a Booth-family spec at "
                             f"wl <= 16, not {spec}")
        self.form = form          # "rows" | "dot" | None (auto: dot)
        self.guard = guard
        self.max_retries = max_retries
        self._apply = fir_apply
        # decode phase hoisted out of the serving hot loop: built once here,
        # reused (gathered by request index) across every flush.  Both
        # backends read the digit planes now — they double as the dot
        # form's correction planes — so always decode eagerly; the bank
        # itself skips the decode for specs no kernel form implements.
        self.bank = PrecodedBank(h_banks, spec)
        self._pending: List[FilterRequest] = []
        self._next_rid = 0
        self._dispatches = 0      # audit cadence counter (guard.budget_every)
        # requests the degradation path gave up on: {rid: repr(error)}.
        # Quarantined, not retried — resubmit explicitly to try again.
        self.failed: Dict[int, str] = {}
        self.stats = {"dispatches": 0, "served": 0, "retries": 0,
                      "bisections": 0, "quarantined": 0, "guard_trips": 0,
                      "exact_reserves": 0}

    def submit(self, signal: np.ndarray, bank: int = 0) -> int:
        """Queue one signal; returns its request id."""
        if not 0 <= bank < len(self.h_banks):
            raise ValueError(f"unknown tap bank {bank}")
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(FilterRequest(rid, np.asarray(signal), bank))
        return rid

    def flush(self) -> Dict[int, np.ndarray]:
        """Serve every pending request; returns {rid: filtered signal}.

        Degradation path: a raising backend is retried up to
        ``max_retries`` times; a batch that still fails is bisected so the
        poison request ends up alone and is *quarantined* (recorded in
        ``self.failed``, ejected from the queue) while every healthy
        request in the same batch is still served.  The queue is dequeued
        before serving on purpose — the old dispatch-before-dequeue order
        meant one poison request re-raised out of every future ``flush``
        and wedged the queue permanently.  With ``guard`` set, per-channel
        runtime guards run on every flush (finite outputs; sampled error
        budget vs the exact-Booth datapath) and a tripped channel is
        transparently re-served on the exact datapath.
        """
        results: Dict[int, np.ndarray] = {}
        while self._pending:
            batch = self._pending[: self.max_channels]
            # dequeue *before* serving: failures below are retried,
            # bisected, and at worst quarantined — never left to wedge
            # the queue for every later flush
            self._pending = self._pending[self.max_channels:]
            self._serve(batch, results)
        return results

    def _stack(self, batch: List[FilterRequest]) -> np.ndarray:
        n = max(len(r.signal) for r in batch)
        x = np.zeros((len(batch), n))
        for c, r in enumerate(batch):
            x[c, : len(r.signal)] = r.signal
        return x

    def _dispatch(self, batch: List[FilterRequest]) -> np.ndarray:
        """One filterbank call with bounded retry; raises when exhausted."""
        x = self._stack(batch)
        h = self.bank.take([r.bank for r in batch])
        for attempt in range(self.max_retries + 1):
            self.stats["dispatches"] += 1
            self._dispatches += 1
            try:
                return np.asarray(self._apply(
                    x, h, self.spec, backend=self.backend, block=self.block,
                    form=self.form))
            except Exception:
                if attempt == self.max_retries:
                    raise
                self.stats["retries"] += 1

    def _serve(self, batch: List[FilterRequest],
               results: Dict[int, np.ndarray]):
        """Serve one batch with bisection quarantine + runtime guards."""
        try:
            y = self._dispatch(batch)
        except Exception as e:
            if len(batch) == 1:
                # the poison request, isolated: eject it instead of
                # livelocking the engine
                self.failed[batch[0].rid] = repr(e)
                self.stats["quarantined"] += 1
                return
            # batch bisection: each half retries independently, so the
            # poison request converges to a singleton and every healthy
            # neighbour is still served this flush
            self.stats["bisections"] += 1
            mid = len(batch) // 2
            self._serve(batch[:mid], results)
            self._serve(batch[mid:], results)
            return
        bad = self._guard_channels(batch, y)
        for c, r in enumerate(batch):
            if c in bad:
                results[r.rid] = self._reserve_exact(r)
            else:
                results[r.rid] = y[c, : len(r.signal)]
            self.stats["served"] += 1

    def _guard_channels(self, batch: List[FilterRequest],
                        y: np.ndarray) -> set:
        """Indices of channels whose runtime guards tripped this dispatch."""
        if self.guard is None:
            return set()
        from ..core.guards import guard_rows
        y_exact = None
        if self.guard.budget_active \
                and self._dispatches % self.guard.budget_every == 0:
            # sampled accuracy audit: the same batch through the exact
            # datapath (one extra dispatch on audited flushes only)
            y_exact = self._exact_batch(batch)
        rep = guard_rows(y, self.guard, y_exact=y_exact)
        if rep.ok:
            return set()
        bad = {c for c in range(len(batch)) if not rep.row_ok[c]}
        self.stats["guard_trips"] += len(bad)
        return bad

    def _exact_spec(self):
        """Exact-Booth comparand at this engine's word length."""
        from ..core.multipliers import MulSpec
        return MulSpec("booth", self.spec.wl, 0)

    def _exact_batch(self, batch: List[FilterRequest]) -> np.ndarray:
        x = self._stack(batch)
        h = self.h_banks[[r.bank for r in batch]]
        return np.asarray(self._apply(x, h, self._exact_spec(),
                                      backend="host", form=None))

    def _reserve_exact(self, r: FilterRequest) -> np.ndarray:
        """Serve one guard-tripped request on the exact datapath."""
        self.stats["exact_reserves"] += 1
        y = self._apply(r.signal[None, :], self.h_banks[[r.bank]],
                        self._exact_spec(), backend="host", form=None)
        return np.asarray(y)[0]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # degradation-path fields: why the request failed (None = healthy),
    # an optional per-request deadline in scheduler steps, whether the
    # output was re-served on the exact datapath after a guard trip
    error: Optional[str] = None
    deadline: Optional[int] = None
    exact: bool = False
    _pending: List[int] = dataclasses.field(default_factory=list)
    _steps: int = 0


class Scheduler:
    """Slot-based LM batch scheduler over the jitted decode step.

    Two scheduling modes:

      * ``continuous=False`` (legacy flush mode): requests are admitted
        only when every resident is at the same depth, prompts are fed one
        token per step through the batched decode, and the whole batch
        walks in lockstep (the homogeneous-position simplification in
        ``step``).
      * ``continuous=True``: per-step admission into any free slot (FIFO,
        at most ``max_prefills_per_step`` admissions per step so a queue
        of long prompts cannot starve resident decodes), the prompt
        prefilled as ONE batch-1 dispatch against the slot's cache slice,
        then per-slot-position batched decode over all residents; slots
        are evicted and their cache slice zeroed for reuse on completion
        or failure.  When the per-row arithmetic is row-independent —
        exact matmuls, or attention-side amm routing whose ``amm_dot``
        vmaps a fresh quantization scale per (slot, head) slice — a
        request's token stream is identical whether it shares the batch
        or runs solo, and with ``kv_codes=True`` its cache bits are too:
        the contract tests/test_serve_continuous.py pins bitwise with
        ``apply_to="attn"``.  MLP amm routing (apply_to "mlp"/"all") is
        the exception: ``amm_dense`` quantizes the activation block with
        one whole-batch scale, so batch composition can move every row's
        code grid.

    ``kv_codes=True`` stores the KV cache as wl-bit int codes plus
    per-block f32 scales (``serve.kv_cache``; requires an active
    Booth-family bitexact amm attention lowering on ``rt``): decode feeds
    frozen cached codes straight into the integer datapath, skipping the
    per-call K/V requantize, and a token's quantized representation never
    drifts as later tokens arrive.

    Degradation policy (all opt-in, all off on the lean default path):

      * a raising decode step is retried ``max_retries`` times with capped
        exponential backoff (``backoff`` / ``backoff_cap`` seconds);
      * if it still raises, each live slot is *probed* one at a time (its
        token alone, padding elsewhere, against a throwaway cache copy) to
        identify which request the failure follows — poison requests fail
        alone (``Request.error`` set, slot recycled) and the surviving
        slots decode normally the same step.  A failure no probe can
        attribute re-raises: that is systemic, not a poison request.
      * with ``guard`` set, per-slot runtime guards run on the step's
        logits (finite check; sampled error budget vs the exact datapath
        every ``guard.budget_every`` steps) and a tripped request is
        re-served from scratch on the *exact* datapath
        (``AmmConfig.mode="off"``), marked ``Request.exact``;
      * ``Request.deadline`` bounds how many scheduler steps a request may
        hold a slot; past it the request fails with error="deadline".

    Retrying a *donating* ``decode_fn`` (launch/serve.py's jitted step
    donates the caches) requires snapshotting the caches before each call
    — that copy is the price of the robust path and is only paid when
    ``max_retries > 0`` or a guard audit needs the pre-step caches.
    ``stats`` counts steps, retries, probes, failures, guard trips,
    exact re-serves, deadline expiries, and completions.
    """

    def __init__(self, cfg: ArchConfig, rt: ModelRuntime, params,
                 batch_slots: int, max_len: int, decode_fn=None, *,
                 prefill_fn=None, continuous: bool = False,
                 kv_codes: bool = False, kv_block: int = KV_BLOCK,
                 max_prefills_per_step: int = 1,
                 guard: Optional[GuardConfig] = None, max_retries: int = 0,
                 backoff: float = 0.0, backoff_cap: float = 1.0):
        self.cfg, self.rt, self.params = cfg, rt, params
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int32)
        self.max_len = max_len
        if kv_codes:
            if not rt.amm.attn_active or rt.amm.attn_lowering is None:
                raise ValueError(
                    "kv_codes stores the cache as Broken-Booth int codes; "
                    "it requires an active Booth-family bitexact amm "
                    "attention lowering (AmmConfig mode='bitexact', "
                    "Booth-family mul, apply_to 'attn'/'all')")
            if guard is not None and guard.budget_active:
                raise ValueError(
                    "the guard budget audit replays the step on the exact "
                    "datapath, which cannot read an int-code cache — use "
                    "finite-only guards or kv_codes=False")
            self.caches = init_code_cache(
                cfg, batch_slots, max_len,
                wl=rt.amm.attn_lowering[0], block=kv_block)
        else:
            self.caches = init_cache(cfg, batch_slots, max_len)
        self.continuous = continuous
        self.kv_codes = kv_codes
        self.max_prefills_per_step = max_prefills_per_step
        self._bax = batch_axis_tree(
            cache_logical_axes(cfg, kv_codes=kv_codes))
        self.queue: List[Request] = []
        self.decode_fn = decode_fn
        self.prefill_fn = prefill_fn
        self.guard = guard
        self.max_retries = max_retries
        self.backoff = backoff
        self.backoff_cap = backoff_cap
        self.stats = {"steps": 0, "decoded": 0, "completed": 0,
                      "prefills": 0, "retries": 0, "probes": 0,
                      "failed": 0, "guard_trips": 0, "exact_reserves": 0,
                      "deadline_expired": 0}
        # serving weights are fixed: hoist the bitexact datapath's weight
        # quantize + Booth digit decode out of the decode loop (None for
        # amm modes with nothing to cache).  A supplied decode_fn owns its
        # own closure (launch/serve.py bakes the planes into the jitted
        # fn) — only the fallback path needs a cache here, so don't build
        # and hold a second copy of the (wl//2, K, N) planes.
        self.amm_planes = (lm_amm_planes(cfg, rt.amm, params)
                           if decode_fn is None else None)

    def submit(self, req: Request):
        """Queue one request; invalid specs raise here, not mid-serve.

        A prompt of ``max_len`` or more tokens can never produce a token
        (the cache has no position left after the prefill), so it is
        rejected at submit time — the old behaviour was a scheduler
        livelock.  Empty prompts are legal: decoding starts from token 0.
        """
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1, "
                             f"got {req.max_new}")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"cannot fit max_len={self.max_len} (needs at least one "
                f"free position to decode)")
        self.queue.append(req)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.pos[i] = 0
                req._pending = list(req.prompt)     # tokens still to feed
                req._steps = 0

    @staticmethod
    def _pos_arr(pos):
        """Decode position operand: scalar (flush mode) or (B,) vector."""
        return jnp.asarray(pos, jnp.int32)

    def _default_fn(self, p, t, c, q):
        logits, _, new_c = lm_apply(
            p, self.cfg, self.rt, jnp.asarray(t), mode="decode",
            caches=c, pos=self._pos_arr(q), amm_planes=self.amm_planes)
        return logits[:, -1], new_c

    def _default_prefill(self, p, t, c):
        logits, _, new_c = lm_apply(
            p, self.cfg, self.rt, jnp.asarray(t), mode="decode",
            caches=c, pos=jnp.int32(0), amm_planes=self.amm_planes)
        return logits[:, -1], new_c

    def _fail(self, i: int, reason: str):
        s = self.slots[i]
        s.error = reason
        s.done = True
        self.slots[i] = None
        self.pos[i] = 0
        self.stats["failed"] += 1

    def _snapshot(self):
        """Host-independent copy of the caches (donation-safe retry)."""
        return jax.tree.map(jnp.copy, self.caches)

    def _probe_poison(self, fn, toks, pos, live) -> List[int]:
        """Which live slots does the decode failure follow?

        Each probe decodes one slot's real token with padding everywhere
        else, against a throwaway cache copy (a donating fn consumes it —
        which is fine, it is a copy).  Deterministic poison follows its
        slot; a failure that no single-slot probe reproduces is systemic.
        """
        poison = []
        for i in live:
            t = np.zeros_like(toks)
            t[i] = toks[i]
            self.stats["probes"] += 1
            try:
                fn(self.params, jnp.asarray(t), self._snapshot(),
                   self._pos_arr(pos))
            except Exception:
                poison.append(i)
        return poison

    def _decode_isolated(self, fn, toks, pos, live):
        """The decode step with retry + poison isolation.

        Returns (logits, live) — ``live`` shrinks when poison requests are
        failed out.  Returns (None, live) when nothing is left to decode
        this step; re-raises when the failure is systemic.
        """
        donating = self.decode_fn is not None
        last = None
        for attempt in range(self.max_retries + 1):
            backup = self._snapshot() if donating and self.max_retries \
                else None
            try:
                logits, self.caches = fn(self.params, jnp.asarray(toks),
                                         self.caches, self._pos_arr(pos))
                return logits, live
            except Exception as e:
                last = e
                if backup is not None:
                    self.caches = backup
                if attempt < self.max_retries:
                    self.stats["retries"] += 1
                    if self.backoff > 0:
                        time.sleep(min(self.backoff * (2 ** attempt),
                                       self.backoff_cap))
        if self.max_retries == 0 and donating:
            # no retry budget means no pre-call snapshot was taken and a
            # donating fn has consumed the caches: nothing to salvage
            raise last
        poison = self._probe_poison(fn, toks, pos, live)
        if not poison:
            raise last            # systemic: every single-slot probe passed
        for i in poison:
            self._fail(i, f"decode failed: {last!r}")
        live = [i for i in live if i not in poison]
        if not live:
            return None, live
        toks = toks.copy()
        for i in poison:
            toks[i] = 0
        logits, self.caches = fn(self.params, jnp.asarray(toks),
                                 self.caches, self._pos_arr(pos))
        return logits, live

    def _guard_slots(self, logits, toks, pos, pre_caches, live) -> List[int]:
        """Live slots whose runtime guards tripped on this step's logits."""
        if self.guard is None:
            return []
        arr = np.asarray(logits)
        ok = finite_rows(arr) if self.guard.finite \
            else np.ones(arr.shape[0], bool)
        if self.guard.budget_active and pre_caches is not None \
                and self.stats["steps"] % self.guard.budget_every == 0:
            # sampled accuracy audit: the same step on the exact datapath
            exact_logits, _ = self._exact_fn()(self.params,
                                               jnp.asarray(toks),
                                               pre_caches,
                                               self._pos_arr(pos))
            err = np.abs(arr.astype(np.float64)
                         - np.asarray(exact_logits, np.float64))
            ok &= np.where(np.isfinite(err), err, np.inf).mean(axis=-1) \
                <= self.guard.budget_abs
        tripped = [i for i in live if not ok[i]]
        self.stats["guard_trips"] += len(tripped)
        return tripped

    def _rt_exact(self) -> ModelRuntime:
        """This scheduler's runtime with the approximate datapath off."""
        from ..models.common import AmmRuntime
        cfg_off = dataclasses.replace(self.rt.amm.cfg, mode="off")
        return dataclasses.replace(self.rt, amm=AmmRuntime(cfg_off))

    def _exact_fn(self):
        rt = self._rt_exact()

        def fn(p, t, c, q):
            logits, _, new_c = lm_apply(p, self.cfg, rt, jnp.asarray(t),
                                        mode="decode", caches=c, pos=q)
            return logits[:, -1], new_c
        return fn

    def _reserve_exact(self, req: Request):
        """Regenerate one guard-tripped request on the exact datapath.

        From-scratch greedy decode at batch 1 — the robust slow path: a
        guard trip means the approximate output cannot be trusted, so the
        whole request replays on ``AmmConfig.mode="off"``.
        """
        self.stats["exact_reserves"] += 1
        fn = self._exact_fn()
        caches = init_cache(self.cfg, 1, self.max_len)
        req.out = []
        pending = list(req.prompt)
        tok = pending.pop(0) if pending else 0
        pos = 0
        while len(req.out) < req.max_new and pos < self.max_len - 1:
            logits, caches = fn(self.params,
                                jnp.asarray([[tok]], jnp.int32), caches,
                                jnp.int32(pos))
            pos += 1
            if pending:
                tok = pending.pop(0)
            else:
                tok = int(np.asarray(jnp.argmax(logits, axis=-1))[0])
                req.out.append(tok)
        req.exact = True
        req.done = True

    # ------------------------------------------------- continuous batching
    def _finish(self, i: int):
        """Complete slot ``i``: evict and free it for the next admission."""
        s = self.slots[i]
        s.done = True
        self.slots[i] = None
        self.pos[i] = 0
        self.stats["completed"] += 1

    def _prefill_slot(self, i: int):
        """Prefill slot ``i``'s prompt as one batch-1 dispatch.

        The slot's cache slice is carved out (``slot_take``), the whole
        prompt runs through the prefill fn at position 0, and the slice is
        written back — resident decodes in other slots are untouched, so a
        long prompt costs them nothing but wall-clock.  The prefill's last
        logits are the model's prediction past the prompt: the first
        generated token falls out of the prefill itself.  Empty prompts
        prefill the single pad token 0, matching flush-mode semantics
        (decoding starts from token 0).
        """
        req = self.slots[i]
        toks = list(req.prompt) or [0]
        fn = self.prefill_fn or self._default_prefill
        sub = slot_take(self.caches, self._bax, i)
        last = None
        for attempt in range(self.max_retries + 1):
            try:
                logits, sub = fn(self.params, jnp.asarray([toks], jnp.int32),
                                 sub)
                break
            except Exception as e:
                last = e
                if attempt < self.max_retries:
                    self.stats["retries"] += 1
                    if self.backoff > 0:
                        time.sleep(min(self.backoff * (2 ** attempt),
                                       self.backoff_cap))
        else:
            self._fail(i, f"prefill failed: {last!r}")
            return
        self.caches = slot_put(self.caches, self._bax, sub, i)
        self.pos[i] = len(toks)
        self.stats["prefills"] += 1
        self.stats["decoded"] += len(toks)
        req._pending = []
        req.out.append(int(np.asarray(jnp.argmax(logits, axis=-1)
                                      ).reshape(-1)[0]))
        if len(req.out) >= req.max_new or self.pos[i] >= self.max_len - 1:
            self._finish(i)

    def _step_continuous(self) -> int:
        """One continuous-batching step: admit, prefill, decode residents.

        Admission is FIFO into free slots, capped at
        ``max_prefills_per_step`` per step — the prefill/decode
        disaggregation knob: residents decode every step regardless of how
        deep the prompt queue is.  Each admission zeroes the slot's cache
        slice (stale codes/values and frozen block scales from the
        previous occupant) before prefilling.  Freshly admitted slots join
        the same step's decode — their (token, position) trajectory is
        self-contained, so step alignment cannot change any request's
        stream.
        """
        admitted = 0
        for i in range(len(self.slots)):
            if not self.queue or admitted >= self.max_prefills_per_step:
                break
            if self.slots[i] is None:
                req = self.queue.pop(0)
                self.slots[i] = req
                req._steps = 0
                req._pending = []
                self.pos[i] = 0
                self.caches = reset_slot(self.caches, self._bax, i)
                self._prefill_slot(i)    # may fail or finish the slot
                admitted += 1
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        self.stats["steps"] += 1
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i in live:
            toks[i, 0] = self.slots[i].out[-1]
        pos = self.pos.copy()   # (B,): dead slots write pad at 0, wiped on
        fn = self.decode_fn or self._default_fn       # the next admission
        audit = (self.guard is not None and self.guard.budget_active
                 and self.stats["steps"] % self.guard.budget_every == 0)
        pre_caches = self._snapshot() if audit else None
        n_live = len(live)
        logits, live = self._decode_isolated(fn, toks, pos, live)
        if logits is None:
            return n_live
        for i in self._guard_slots(logits, toks, pos, pre_caches, live):
            self._reserve_exact(self.slots[i])
            self.slots[i] = None
            self.pos[i] = 0
            live = [j for j in live if j != i]
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in live:
            s = self.slots[i]
            self.pos[i] += 1
            s._steps += 1
            self.stats["decoded"] += 1
            s.out.append(int(nxt[i]))
            if len(s.out) >= s.max_new or self.pos[i] >= self.max_len - 1:
                self._finish(i)
            elif s.deadline is not None and s._steps >= s.deadline:
                self._fail(i, "deadline")
                self.stats["deadline_expired"] += 1
        return n_live

    def step(self) -> int:
        """One decode step over all live slots; returns #live requests."""
        if self.continuous:
            return self._step_continuous()
        self._admit()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return 0
        self.stats["steps"] += 1
        toks = np.zeros((len(self.slots), 1), np.int32)
        for i in live:
            s = self.slots[i]
            # peek, don't pop: the prompt token is only consumed once the
            # decode call commits, so a retried step does not lose it
            toks[i, 0] = (s._pending[0] if s._pending
                          else (s.out[-1] if s.out else 0))
        pos = int(self.pos[live[0]])   # homogeneous-pos simplification
        fn = self.decode_fn or self._default_fn
        audit = (self.guard is not None and self.guard.budget_active
                 and self.stats["steps"] % self.guard.budget_every == 0)
        pre_caches = self._snapshot() if audit else None
        n_live = len(live)
        logits, live = self._decode_isolated(fn, toks, pos, live)
        if logits is None:
            return n_live
        for i in self._guard_slots(logits, toks, pos, pre_caches, live):
            self._reserve_exact(self.slots[i])
            self.slots[i] = None
            live = [j for j in live if j != i]
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in live:
            s = self.slots[i]
            self.pos[i] += 1
            s._steps += 1
            self.stats["decoded"] += 1
            if s._pending:
                s._pending.pop(0)       # committed: the step consumed it
            if not s._pending:           # prompt drained: this step's
                # logits are the model's prediction past the prompt, so
                # the same step that consumes the last prompt token also
                # emits the first generated token (pre-robustness parity)
                s.out.append(int(nxt[i]))
                if len(s.out) >= s.max_new:
                    s.done = True
                    self.slots[i] = None
                    self.stats["completed"] += 1
                    continue
            if self.pos[i] >= self.max_len - 1:
                # cache positions exhausted: finish (or fail, mid-prompt)
                # whether or not the prompt is drained — the old in-branch
                # check livelocked on prompts at the length cap
                if s._pending:
                    self._fail(i, "context exhausted mid-prompt")
                else:
                    s.done = True
                    self.slots[i] = None
                    self.stats["completed"] += 1
            elif s.deadline is not None and s._steps >= s.deadline:
                self._fail(i, "deadline")
                self.stats["deadline_expired"] += 1
        return n_live
