"""Int-code KV cache: wl-bit codes + per-(layer, slot, block, kv-head)
float32 scales.

The serving-side twin of ``models.transformer.init_cache``: instead of
bf16/f32 K/V values the cache holds the quantized codes the approximate
datapath would derive anyway (``kernels.ref.amm_quantize`` inside
``bbm_matmul_dynamic``), frozen at write time, plus one f32 scale per
(layer, slot, seq-block, kv-head).  Decode feeds the codes straight into
``kernels.bbm_matmul.bbm_matmul_coded`` (``models.attention.
decode_attention_codes``), skipping the per-call K/V requantize — and,
because codes never change after their write, every served token's bits
are independent of later arrivals (the scale-drift fix pinned in
tests/test_amm_attention.py).

Layout (GQA / dense families)::

    k_codes, v_codes: (layers, batch, max_len, kv_heads, head_dim)  intN
    k_scale, v_scale: (layers, batch, n_blocks, kv_heads)           f32

with ``n_blocks = max_len // block`` and intN = int8 for wl <= 8 else
int16.  MLA caches the compressed latent: ``lat_codes`` (layers, batch,
max_len, kv_latent + rope) + ``lat_scale`` (layers, batch, n_blocks).
A scale of 0.0 marks a never-written block (real scales are floored at
1e-12); the first write touching a block freezes its scale
(``models.attention.code_cache_update``).

Memory: at wl = 8 the code planes are exactly half the bf16 cache bytes
(int8 vs 2-byte floats); the scale planes add 4 bytes per block x head —
``4 / (block * head_dim)`` of the code bytes at default geometry, reported
separately by ``benchmarks/serve_load.py`` rather than folded into the
headline ratio.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig

__all__ = ["KV_BLOCK", "batch_axis_tree", "cache_nbytes",
           "code_cache_logical_axes", "code_dtype", "float_cache_nbytes",
           "init_code_cache", "memory_report", "reset_slot", "slot_take",
           "slot_put"]

# default seq-block granularity of the frozen scales: small enough that an
# envelope-edge token only coarsens its own block's grid, large enough
# that scale bytes stay ~1% of code bytes at head_dim 64
KV_BLOCK = 16


def code_dtype(wl: int):
    """Narrowest signed integer dtype holding wl-bit codes."""
    if wl <= 8:
        return jnp.int8
    if wl <= 16:
        return jnp.int16
    raise ValueError(f"wl={wl} exceeds the 16-bit code envelope")


def init_code_cache(cfg: ArchConfig, batch: int, max_len: int, *, wl: int,
                    block: int = KV_BLOCK) -> Dict[str, Any]:
    """Zeroed int-code decode cache for one full model (layer-stacked).

    Zero codes + zero scales are the empty state by construction: zero
    codes contribute nothing under either Broken-Booth truncation kind,
    and 0.0 scales mark every block as never written.
    """
    if max_len % block:
        raise ValueError(f"max_len={max_len} not a multiple of the scale "
                         f"block {block}")
    nb = max_len // block
    dt = code_dtype(wl)
    n = cfg.n_layers
    if cfg.family == "moe" and cfg.use_mla:
        lat = cfg.kv_lora_rank + cfg.qk_rope_dim
        return {"lat_codes": jnp.zeros((n, batch, max_len, lat), dt),
                "lat_scale": jnp.zeros((n, batch, nb), jnp.float32)}
    if (cfg.family in ("dense", "vlm", "audio", "moe")
            and not cfg.is_encoder_decoder):
        hd = cfg.resolved_head_dim
        kv = cfg.n_kv_heads
        return {"k_codes": jnp.zeros((n, batch, max_len, kv, hd), dt),
                "v_codes": jnp.zeros((n, batch, max_len, kv, hd), dt),
                "k_scale": jnp.zeros((n, batch, nb, kv), jnp.float32),
                "v_scale": jnp.zeros((n, batch, nb, kv), jnp.float32)}
    raise ValueError(f"int-code KV cache supports dense/GQA and MLA decode "
                     f"caches, not family {cfg.family!r}"
                     + (" (encoder-decoder)" if cfg.is_encoder_decoder
                        else ""))


def code_cache_logical_axes(cfg: ArchConfig) -> Dict[str, Any]:
    """Logical axis names per code-cache leaf (``spec_to_pspec`` input).

    The "blocks" axis has no sharding rule on purpose — scales are tiny
    and replicate; codes shard exactly like the float cache they replace.
    """
    if cfg.family == "moe" and cfg.use_mla:
        return {"lat_codes": ("layers", "batch", "seq_model", "kv_latent"),
                "lat_scale": ("layers", "batch", "blocks")}
    kvax = ("layers", "batch", "seq", "kv_heads", "head_dim")
    scax = ("layers", "batch", "blocks", "kv_heads")
    return {"k_codes": kvax, "v_codes": kvax,
            "k_scale": scax, "v_scale": scax}


def cache_nbytes(cache) -> int:
    """Total bytes of a (possibly abstract) cache pytree."""
    return sum(int(np.prod(c.shape)) * jnp.dtype(c.dtype).itemsize
               for c in jax.tree.leaves(cache))


def float_cache_nbytes(cfg: ArchConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16) -> int:
    """Bytes of the float cache the code cache replaces (no allocation)."""
    from ..models.transformer import init_cache
    structs = jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype=dtype))
    return cache_nbytes(structs)


# ------------------------------------------------------------ slot surgery
# The continuous scheduler addresses one slot of the batch axis at a time:
# admission resets it, prefill runs on a batch-1 slice and writes it back.
# The batch axis sits at a different depth per leaf (hybrid ssm/conv nest
# it under a group axis), so every helper takes a matching pytree of batch
# axis indices (``ax_tree``), derived once from the logical axes.

def batch_axis_tree(axes: Dict[str, Any]) -> Dict[str, Any]:
    """Map a logical-axes tree to per-leaf batch-axis indices."""
    return jax.tree.map(lambda ax: ax.index("batch"), axes,
                        is_leaf=lambda x: isinstance(x, tuple))


def slot_take(cache, ax_tree, i: int):
    """Batch-1 slice of slot ``i`` from every leaf (shape kept)."""
    return jax.tree.map(
        lambda c, ax: jax.lax.slice_in_dim(c, i, i + 1, axis=ax),
        cache, ax_tree)


def slot_put(cache, ax_tree, sub, i: int):
    """Write a batch-1 slice back into slot ``i`` of every leaf."""
    return jax.tree.map(
        lambda c, s, ax: jax.lax.dynamic_update_slice_in_dim(
            c, s.astype(c.dtype), i, axis=ax),
        cache, sub, ax_tree)


def reset_slot(cache, ax_tree, i: int):
    """Zero slot ``i`` in every leaf — codes, scales and float state alike.

    Zero is the empty state for both cache kinds: zeroed float rows never
    move a dynamic-range scale, zeroed codes contribute nothing to either
    truncation kind, and zeroed block scales re-arm first-touch freezing.
    """
    def zero(c, ax):
        idx = (slice(None),) * ax + (i,)
        return c.at[idx].set(0)
    return jax.tree.map(zero, cache, ax_tree)


def memory_report(cfg: ArchConfig, batch: int, max_len: int, *, wl: int,
                  block: int = KV_BLOCK) -> Dict[str, Any]:
    """Code-vs-bf16 cache byte accounting (the BENCH_serve.json rows)."""
    structs = jax.eval_shape(
        lambda: init_code_cache(cfg, batch, max_len, wl=wl, block=block))
    code = sum(int(np.prod(c.shape)) * jnp.dtype(c.dtype).itemsize
               for k, c in structs.items() if k.endswith("_codes"))
    scale = sum(int(np.prod(c.shape)) * jnp.dtype(c.dtype).itemsize
                for k, c in structs.items() if k.endswith("_scale"))
    bf16 = float_cache_nbytes(cfg, batch, max_len)
    return {"code_bytes": code, "scale_bytes": scale, "bf16_bytes": bf16,
            "ratio_codes": bf16 / code,
            "ratio_total": bf16 / (code + scale),
            "scale_overhead": scale / code}
