"""Serving layer: LM prefill/decode engine + batched FIR filterbank path."""
from .engine import FilterbankEngine, FilterRequest, Scheduler

__all__ = ["FilterbankEngine", "FilterRequest", "Scheduler"]
