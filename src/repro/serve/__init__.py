"""Serving layer: LM prefill/decode engine + batched FIR filterbank path."""
from .engine import (FilterbankEngine, FilterRequest, Request, Scheduler,
                     cache_logical_axes, cache_shardings, make_serve_fns)
from .kv_cache import (KV_BLOCK, code_cache_logical_axes, init_code_cache,
                       memory_report)

__all__ = ["FilterbankEngine", "FilterRequest", "KV_BLOCK", "Request",
           "Scheduler", "cache_logical_axes", "cache_shardings",
           "code_cache_logical_axes", "init_code_cache", "make_serve_fns",
           "memory_report"]
