"""Serving launcher: batched decoding with the slot scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --requests 6 --max-new 16 --amm bitexact --vbl 13

--amm bitexact serves through the true Broken-Booth datapath (dot-form
lowering); the Scheduler precodes every approximated weight's digit planes
once at construction, so the per-step cost is the contraction, not the
decode.  --amm-attn widens the routing to the attention score/value
products (``--amm-attn`` alone = apply_to="all", ``--amm-attn attn`` =
attention only); those are activation x activation, so they quantize per
step — there are no weight planes to cache for them.

--continuous switches the Scheduler to continuous batching: requests are
admitted into free slots every step (prefill on a batch-1 slot slice) and
evicted the step they finish, so a long prompt never stalls resident
decodes.  --kv-codes stores the KV cache as wl-bit int codes + per-block
f32 scales (docs/serving.md); it requires --amm bitexact with a
Booth-family --mul and --amm-attn (``validate_serve_flags``).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from ..configs import ARCH_NAMES, get_arch, reduced
from ..configs.base import AmmConfig
from ..models import ModelRuntime, lm_init
from ..serve.engine import Request, Scheduler, make_serve_fns
from . import (add_amm_attn_arg, resolve_amm_apply_to,
               validate_amm_args, validate_serve_flags)
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--amm", choices=["off", "noise", "bitexact"],
                    default="off")
    ap.add_argument("--mul", default="bbm0")
    ap.add_argument("--wl", type=int, default=16)
    ap.add_argument("--vbl", type=int, default=13)
    ap.add_argument("--amm-pallas", action="store_true",
                    help="mode=noise: fused Pallas quant_matmul kernel")
    ap.add_argument("--flash-attn", action="store_true",
                    help="route prefill attention through the flash "
                         "lowering (exact-flash, or flash-amm when "
                         "--amm-attn makes attention amm-active); decode "
                         "keeps the cache path")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: per-step admission into "
                         "free slots, per-request eviction, prefill on "
                         "batch-1 slot slices")
    ap.add_argument("--kv-codes", action="store_true",
                    help="store the KV cache as wl-bit int codes + "
                         "per-block f32 scales; needs --amm bitexact with "
                         "a Booth-family --mul and --amm-attn")
    add_amm_attn_arg(ap)
    args = ap.parse_args(argv)
    apply_to = resolve_amm_apply_to(ap, args)
    validate_amm_args(ap, args)
    validate_serve_flags(ap, args)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode=args.amm, mul=args.mul, wl=args.wl,
                           param=args.vbl, use_pallas=args.amm_pallas,
                           apply_to=apply_to))
    rt = ModelRuntime.build(cfg, use_pallas=args.flash_attn)
    params = lm_init(cfg, jax.random.key(0))
    # jitted decode step with the digit-plane cache baked into the closure:
    # the bitexact datapath's weight decode happens once here, every token
    # after pays contractions only
    mesh = make_host_mesh(1, 1)
    planes = rt.build_planes(cfg, params)
    prefill_j, decode_j = make_serve_fns(cfg, rt, mesh, batch=args.slots,
                                         max_len=args.max_len,
                                         amm_planes=planes,
                                         kv_codes=args.kv_codes)
    sched = Scheduler(cfg, rt, params, args.slots, args.max_len,
                      decode_fn=decode_j,
                      prefill_fn=prefill_j if args.continuous else None,
                      continuous=args.continuous, kv_codes=args.kv_codes)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.perf_counter()
    steps = tokens = 0
    while sched.step():
        steps += 1
    dt = time.perf_counter() - t0
    print(f"[serve] {args.requests} requests in {steps} decode steps, "
          f"{dt:.2f}s")
    return steps


if __name__ == "__main__":
    main()
