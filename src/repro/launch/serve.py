"""Serving launcher: batched decoding with the slot scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_NAMES, get_arch, reduced
from ..models import ModelRuntime, lm_init
from ..serve.engine import Request, Scheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    sched = Scheduler(cfg, rt, params, args.slots, args.max_len)

    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
        sched.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.perf_counter()
    steps = tokens = 0
    while sched.step():
        steps += 1
    dt = time.perf_counter() - t0
    print(f"[serve] {args.requests} requests in {steps} decode steps, "
          f"{dt:.2f}s")
    return steps


if __name__ == "__main__":
    main()
