"""Subsystem package: CLI entry points + shared argparse plumbing."""
from __future__ import annotations

__all__ = ["add_amm_attn_arg", "resolve_amm_apply_to"]


def add_amm_attn_arg(ap) -> None:
    """The shared ``--amm-attn`` flag (train and serve launchers).

    Bare flag -> apply_to="all" (MLPs + attention); ``--amm-attn attn``
    -> attention only.  Attention routing engages only for
    mode="bitexact" with a Booth-family mul — under mode="noise" the
    MLPs still route but attention stays exact (docs/attention.md);
    ``resolve_amm_apply_to`` rejects the combinations that would
    approximate nothing at all.
    """
    ap.add_argument("--amm-attn", nargs="?", const="all", default=None,
                    choices=["attn", "all"],
                    help="route the attention QK^T/PV products through the "
                         "approximate datapath too (bare flag: MLPs + "
                         "attention, apply_to='all'; '--amm-attn attn': "
                         "attention only).  Attention routing needs "
                         "--amm bitexact with a Booth-family --mul; under "
                         "--amm noise the MLPs still route but attention "
                         "stays exact (docs/attention.md)")


def resolve_amm_apply_to(ap, args) -> str:
    """Validate the (--amm, --mul, --amm-attn) combination -> apply_to.

    apply_to="attn" excludes the MLPs and only the bitexact Booth
    datapath has an attention lowering (``kernels.ref.AMM_BOOTH_KINDS``,
    the same registry ``AmmRuntime.attn_active`` consults), so any other
    combination would silently compute the whole model exactly while
    labeled amm — reject it at the CLI instead.
    """
    from ..kernels.ref import AMM_BOOTH_KINDS
    if args.amm_attn == "attn" and not (
            args.amm == "bitexact" and args.mul in AMM_BOOTH_KINDS):
        ap.error("--amm-attn attn routes *only* attention, which needs "
                 "--amm bitexact with a Booth-family --mul; this "
                 "combination would approximate nothing")
    return args.amm_attn or "mlp"
