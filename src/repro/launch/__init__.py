"""Subsystem package: CLI entry points + shared argparse plumbing."""
from __future__ import annotations

__all__ = ["add_amm_attn_arg", "resolve_amm_apply_to",
           "validate_amm_args", "validate_serve_flags"]


def validate_amm_args(ap, args) -> None:
    """Reject invalid (--mul, --wl, --vbl) combinations at parse time.

    Shared by the train and serve launchers so a bad spec fails with one
    clear message before any params are initialized or caches built —
    previously an out-of-range VBL surfaced minutes later as a shape
    error deep in the Booth decode (or, worse, quantized everything to
    zero and "worked").  Checks mirror the datapath's real envelope:

      * unknown multiplier family (``core.MULTIPLIERS`` registry),
      * word length: even (radix-4 Booth pairs bits), 4..16 when an
        approximate mode is on (the int32 dot-form envelope; wl > 16
        only exists on the exact host FIR path),
      * VBL: ``0 <= vbl < wl`` for the BBM families (nullifying every
        bit is no longer a multiplier); kulkarni/bam interpret the knob
        differently and only require it non-negative.
    """
    if args.amm == "off":
        return
    from ..core.multipliers import MULTIPLIERS
    if args.mul not in MULTIPLIERS:
        ap.error(f"unknown --mul {args.mul!r}; choose from "
                 f"{sorted(MULTIPLIERS)}")
    if args.wl % 2 or not 4 <= args.wl <= 16:
        ap.error(f"--wl {args.wl} out of range: the approximate datapath "
                 f"needs an even word length in [4, 16] (int32 dot-form "
                 f"envelope)")
    if args.vbl < 0:
        ap.error(f"--vbl {args.vbl} must be non-negative")
    if args.mul in ("booth", "bbm0", "bbm1") and args.vbl >= args.wl:
        ap.error(f"--vbl {args.vbl} >= --wl {args.wl}: nullifying every "
                 f"product bit leaves no multiplier; VBL must be < WL")


def validate_serve_flags(ap, args) -> None:
    """Reject ``--kv-codes`` combinations the code cache cannot serve.

    The int-code KV cache stores exactly the quantized representation the
    Booth attention lowering consumes, so it only exists when decode
    attention is amm-routed: mode="bitexact", a Booth-family --mul, and
    --amm-attn present.  Anything else would need a float cache anyway —
    fail at parse time instead of deep inside ``Scheduler.__init__``.
    """
    if not getattr(args, "kv_codes", False):
        return
    from ..kernels.ref import AMM_BOOTH_KINDS
    if args.amm != "bitexact":
        ap.error(f"--kv-codes stores Booth codes, which only the bitexact "
                 f"datapath consumes; got --amm {args.amm}")
    if args.mul not in AMM_BOOTH_KINDS:
        ap.error(f"--kv-codes needs a Booth-family --mul "
                 f"({sorted(AMM_BOOTH_KINDS)}); got --mul {args.mul!r}")
    if args.amm_attn is None:
        ap.error("--kv-codes caches the attention operands, so attention "
                 "must be amm-routed: pass --amm-attn (or --amm-attn attn)")


def add_amm_attn_arg(ap) -> None:
    """The shared ``--amm-attn`` flag (train and serve launchers).

    Bare flag -> apply_to="all" (MLPs + attention); ``--amm-attn attn``
    -> attention only.  Attention routing engages only for
    mode="bitexact" with a Booth-family mul — under mode="noise" the
    MLPs still route but attention stays exact (docs/attention.md);
    ``resolve_amm_apply_to`` rejects the combinations that would
    approximate nothing at all.
    """
    ap.add_argument("--amm-attn", nargs="?", const="all", default=None,
                    choices=["attn", "all"],
                    help="route the attention QK^T/PV products through the "
                         "approximate datapath too (bare flag: MLPs + "
                         "attention, apply_to='all'; '--amm-attn attn': "
                         "attention only).  Attention routing needs "
                         "--amm bitexact with a Booth-family --mul; under "
                         "--amm noise the MLPs still route but attention "
                         "stays exact (docs/attention.md)")


def resolve_amm_apply_to(ap, args) -> str:
    """Validate the (--amm, --mul, --amm-attn) combination -> apply_to.

    apply_to="attn" excludes the MLPs and only the bitexact Booth
    datapath has an attention lowering (``kernels.ref.AMM_BOOTH_KINDS``,
    the same registry ``AmmRuntime.attn_active`` consults), so any other
    combination would silently compute the whole model exactly while
    labeled amm — reject it at the CLI instead.
    """
    from ..kernels.ref import AMM_BOOTH_KINDS
    if args.amm_attn == "attn" and not (
            args.amm == "bitexact" and args.mul in AMM_BOOTH_KINDS):
        ap.error("--amm-attn attn routes *only* attention, which needs "
                 "--amm bitexact with a Booth-family --mul; this "
                 "combination would approximate nothing")
    return args.amm_attn or "mlp"
