"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 50 --batch 8 --seq 256 --reduced --amm noise --vbl 13

On this CPU container use --reduced (tiny same-family config); on a real
fleet drop it and point --mesh-data/--mesh-model at the slice.  The loop is
the fault-tolerant one (checkpoint/restart, straggler monitor).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from ..configs import ARCH_NAMES, get_arch, reduced
from ..configs.base import AmmConfig
from ..data.pipeline import DataConfig, batches
from ..models import ModelRuntime
from ..parallel.logical import tree_shardings
from ..train.loop import LoopConfig, train_loop
from ..train.optimizer import OptConfig
from ..train.trainstep import TrainConfig, make_train_step, init_train_state
from . import (add_amm_attn_arg, resolve_amm_apply_to,
               validate_amm_args)
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--amm", choices=["off", "noise", "bitexact"],
                    default="off")
    ap.add_argument("--mul", default="bbm0")
    ap.add_argument("--wl", type=int, default=16)
    ap.add_argument("--vbl", type=int, default=13)
    ap.add_argument("--amm-pallas", action="store_true",
                    help="mode=noise: route through the fused Pallas "
                         "quant_matmul kernel (TPU fast path; interpreted "
                         "on CPU).  mode=bitexact needs no flag — it "
                         "always lowers to the dot-form contractions.")
    ap.add_argument("--flash-attn", action="store_true",
                    help="route attention through the flash lowering "
                         "(exact-flash, or flash-amm when --amm-attn makes "
                         "attention amm-active); gradients take the "
                         "chunked path's straight-through rule either way")
    add_amm_attn_arg(ap)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args(argv)
    apply_to = resolve_amm_apply_to(ap, args)
    validate_amm_args(ap, args)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode=args.amm, mul=args.mul, wl=args.wl,
                           param=args.vbl, use_pallas=args.amm_pallas,
                           apply_to=apply_to))
    rt = ModelRuntime.build(cfg, use_pallas=args.flash_attn)
    mesh = make_host_mesh(args.mesh_data, args.mesh_model)
    tc = TrainConfig(microbatches=args.microbatches,
                     opt=OptConfig(lr=args.lr, total_steps=args.steps))
    step_fn = make_train_step(cfg, rt, tc, mesh, global_batch=args.batch,
                              with_encoder=cfg.is_encoder_decoder)
    params, opt = init_train_state(cfg, tc, mesh, jax.random.key(0))

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    lc = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir)
    if cfg.is_encoder_decoder:
        enc = jnp.zeros((args.batch, cfg.encoder_len, cfg.d_model),
                        jnp.float32)
        raw_step = step_fn
        step_fn = lambda p, o, t, l, r: raw_step(p, o, t, l, r, enc)

    def data_iter(start):
        for toks, labels, step in batches(dc, start):
            yield jnp.asarray(toks), jnp.asarray(labels), step

    params, opt, hist = train_loop(
        step_fn, params, opt, data_iter, lc, rng=jax.random.key(42))
    print(f"[train] done: {len(hist)} steps, "
          f"final loss {hist[-1]['loss']:.4f}, "
          f"stragglers flagged: {sum(h['straggler'] for h in hist)}")
    return hist


if __name__ == "__main__":
    main()
