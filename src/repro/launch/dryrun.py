import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import pulls in jax: the CPU
# backend locks its device count at first initialization.

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell with ShapeDtypeStruct inputs (no allocation), and record:

  * memory_analysis()  — proves the step fits per-device HBM,
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes   — parsed from the post-SPMD optimized HLO.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all            # every assigned cell
Results append incrementally to --out (default benchmarks/dryrun_results.json).
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCH_NAMES, SHAPES, get_arch
from ..configs.base import ArchConfig, ShapeConfig
from ..models import ModelRuntime, init_cache, lm_logical_axes, lm_table
from ..models.common import Spec
from .mesh import HW, make_production_mesh

DEFAULT_OUT = "benchmarks/dryrun_results.json"


# ------------------------------------------------------------- input specs
def param_structs(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree for the parameters (weak-type-correct)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        lm_table(cfg), is_leaf=lambda x: isinstance(x, Spec))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode: one new token against a cache of length s
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    if cfg.is_encoder_decoder:
        out["encoder_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    if shape.is_decode or shape.kind == "prefill":
        out["caches"] = jax.eval_shape(
            lambda: init_cache(cfg, b, s))
    return out


# --------------------------------------------------------- HLO collective scan
_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_GROUP_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUP_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Estimated per-device wire bytes per collective family (ring costs)."""
    totals = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
              "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(totals, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        _, dt, dims, op = m.groups()
        nbytes = _DTYPE_BYTES.get(dt, 4) * int(
            np.prod([int(x) for x in dims.split(",") if x] or [1]))
        n = 1
        g = _GROUP_RE.search(line)
        if g:
            n = max(len(g.group(1).split(",")), 1)
        else:
            g2 = _GROUP_V2.search(line)
            if g2:
                n = int(g2.group(2))
        if n <= 1:
            continue
        ring = (n - 1) / n
        if op == "all-gather":
            wire = nbytes * ring                     # result is full size
        elif op == "reduce-scatter":
            wire = nbytes * (n - 1)                  # result is 1/n input
        elif op == "all-reduce":
            wire = 2 * nbytes * ring
        elif op == "all-to-all":
            wire = nbytes * ring
        else:                                        # collective-permute
            wire = nbytes
        totals[op] += wire
        counts[op] += 1
    return {"bytes": totals, "counts": counts,
            "total": sum(totals.values())}


# ----------------------------------------------------------------- lowering
def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               microbatches: int = 4, remat: bool = True,
               opt_state_dtype: str = "float32",
               attn_remat: bool = False, shard_heads: bool = False,
               causal_skip: bool = False, moe_gather: bool = False,
               p_bf16: bool = False,
               extra_rules: Optional[dict] = None) -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    rt = ModelRuntime.build(cfg, remat=remat, attn_remat=attn_remat,
                            shard_heads=shard_heads, causal_skip=causal_skip,
                            moe_gather_weights=moe_gather,
                            attn_p_bf16=p_bf16)
    specs = input_specs(cfg, shape)
    p_struct = param_structs(cfg)

    t0 = time.time()
    # lower under the mesh context so P-based sharding constraints resolve
    mesh_ctx = mesh
    if shape.kind == "train":
        from ..train.optimizer import OptConfig
        from ..train.trainstep import TrainConfig, make_train_step
        from ..train.optimizer import init_opt
        tc = TrainConfig(
            microbatches=microbatches,
            opt=OptConfig(state_dtype=getattr(jnp, opt_state_dtype)))
        step = make_train_step(cfg, rt, tc, mesh,
                               with_encoder=cfg.is_encoder_decoder,
                               global_batch=shape.global_batch)
        opt_struct = jax.eval_shape(lambda p: init_opt(p, tc.opt), p_struct)
        key_struct = jax.eval_shape(lambda: jax.random.key(0))
        args = [p_struct, opt_struct, specs["tokens"], specs["labels"],
                key_struct]
        if cfg.is_encoder_decoder:
            args.append(specs["encoder_embeds"])
        with mesh_ctx:
            lowered = step.lower(*args)
    else:
        from ..serve.engine import make_serve_fns
        b = shape.global_batch
        prefill_j, decode_j = make_serve_fns(cfg, rt, mesh, batch=b,
                                             max_len=shape.seq_len)
        enc = ((specs["encoder_embeds"],) if cfg.is_encoder_decoder else ())
        with mesh_ctx:
            if shape.kind == "prefill":
                lowered = prefill_j.lower(p_struct, specs["tokens"],
                                          specs["caches"], *enc)
            else:
                pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = decode_j.lower(p_struct, specs["tokens"],
                                         specs["caches"], pos_struct, *enc)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    cost_info = {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float)) and k in
                 ("flops", "bytes accessed", "transcendentals",
                  "utilization operand 0 {}", "bytes accessed output {}")}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    # dump the optimized HLO for the trip-count-aware roofline analysis
    import gzip
    hlo_dir = os.path.join(os.path.dirname(DEFAULT_OUT) or ".", "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    vtag = ""
    if attn_remat or shard_heads or causal_skip or moe_gather or p_bf16 \
            or opt_state_dtype != "float32" or microbatches != 4:
        vtag = f"_v-ar{int(attn_remat)}-sh{int(shard_heads)}" \
               f"-cs{int(causal_skip)}-mg{int(moe_gather)}-pb{int(p_bf16)}" \
               f"-od{opt_state_dtype}-mb{microbatches}"
    hlo_path = os.path.join(
        hlo_dir, f"{arch}_{shape_name}_{mesh_tag}{vtag}.hlo.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(hlo)

    return {
        "hlo_path": hlo_path,
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": shape.kind,
        "microbatches": microbatches if shape.kind == "train" else None,
        "remat": remat,
        "attn_remat": attn_remat,
        "shard_heads": shard_heads,
        "opt_state_dtype": opt_state_dtype if shape.kind == "train" else None,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": mem_info,
        "cost": cost_info,
        "collectives": coll,
        "ok": True,
    }


def append_result(res: Dict[str, Any], path: str):
    data = []
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    # replace a previous run of the same cell
    keyf = lambda r: (r.get("arch"), r.get("shape"), r.get("mesh"),
                      r.get("variant", ""))
    data = [r for r in data if keyf(r) != keyf(res)]
    data.append(res)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def eligible(arch: str, shape_name: str) -> bool:
    cfg = get_arch(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False        # full-attention archs skip 500k: quadratic
                            # score memory is out of budget at that length
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--attn-remat", action="store_true")
    ap.add_argument("--shard-heads", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--moe-gather", action="store_true")
    ap.add_argument("--p-bf16", action="store_true")
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--variant", default="",
                    help="label for perf-iteration variants")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_NAMES:
            for sname in SHAPES:
                if eligible(a, sname):
                    cells.append((a, sname))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch, sname in cells:
        for mp in meshes:
            tag = f"{arch} x {sname} x {'2x16x16' if mp else '16x16'}"
            try:
                res = lower_cell(arch, sname, multi_pod=mp,
                                 microbatches=args.microbatches,
                                 remat=not args.no_remat,
                                 attn_remat=args.attn_remat,
                                 shard_heads=args.shard_heads,
                                 causal_skip=args.causal_skip,
                                 moe_gather=args.moe_gather,
                                 p_bf16=args.p_bf16,
                                 opt_state_dtype=args.opt_dtype)
                if args.variant:
                    res["variant"] = args.variant
                append_result(res, args.out)
                print(f"[dryrun] OK  {tag}  compile={res['t_compile_s']}s "
                      f"flops={res['cost'].get('flops', 0):.3e} "
                      f"coll={res['collectives']['total']:.3e}B")
            except Exception as e:  # noqa: BLE001
                res = {"arch": arch, "shape": sname,
                       "mesh": "2x16x16" if mp else "16x16",
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                if args.variant:
                    res["variant"] = args.variant
                append_result(res, args.out)
                print(f"[dryrun] FAIL {tag}: {e}")


if __name__ == "__main__":
    main()
