"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import
and then calls it.

Production target: TPU v5e, 16x16 = 256 chips per pod; multi-pod doubles
over the data-center network on a leading "pod" axis.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "HW"]

# hardware constants for the roofline analysis (TPU v5e)
HW = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link
    "hbm_bytes": 16e9,             # capacity per chip
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))
