"""Chameleon-34B: early-fusion VLM; VQ image tokens arrive pre-tokenized via
the stub frontend (they are ordinary vocab entries) [arXiv:2405.09818]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    head_dim=128, d_ff=22016, vocab=65536, qk_norm=True,
    frontend="vision",
)
