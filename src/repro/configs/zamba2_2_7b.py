"""Zamba2-2.7B hybrid: Mamba2 backbone + shared attention block every 6
layers (weights reused; the per-invocation LoRA deltas of the reference
implementation are deliberately omitted — the shared-block scheme itself
is what the hybrid family exercises) [arXiv:2411.15242]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    head_dim=80, d_ff=10240, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=128,
    shared_attn_every=6,
)
