"""Whisper-base backbone: enc-dec transformer; conv audio frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356].

TPU adaptation note: positional encoding is RoPE here (the original uses
sinusoidal/learned); the assignment covers the transformer backbone only.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab=51865,
    is_encoder_decoder=True, n_encoder_layers=6, encoder_len=1500,
    frontend="audio",
)
