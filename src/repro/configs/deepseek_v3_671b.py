"""DeepSeek-V3 671B: MLA + 1 shared/256 routed top-8 MoE + MTP.

[arXiv:2412.19437; hf].  Assigned spec: 61L d_model=7168 128H d_ff=2048
(routed expert width) vocab=129280.  First 3 layers dense (d_ff 18432) and
MTP depth 1 per the paper.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    head_dim=128, d_ff=18432, vocab=129280,
    n_experts=256, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_k_dense=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    mtp_depth=1, rope_theta=1e4,
)
