"""Architecture + shape + approximate-multiplier configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["AmmConfig", "ArchConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class AmmConfig:
    """Approximate-matmul (the paper's technique) as a model-level feature.

    mode:
      "off"      — exact bf16/f32 matmuls (baseline hardware)
      "noise"    — WL-bit fixed-point quantization + calibrated white-noise
                   error injection (paper §II.B, scales to 671B)
      "bitexact" — the true Broken-Booth datapath, lowered to dense
                   contractions (kernels.bbm_matmul_scaled: exact-dot +
                   low-bit correction, O(B*N) live memory, bit-identical
                   to the scalar oracle kernels.ref.amm_dense_ref).
                   Non-Booth families (bam/kulkarni/etm) still take the
                   scalar closed forms: reduced configs only for those.
    """
    mode: str = "off"
    mul: str = "bbm0"          # multiplier family (core.multipliers registry)
    wl: int = 16
    param: int = 13            # VBL (or K for kulkarni)
    apply_to: str = "mlp"      # which matmul families are approximated:
                               #   "mlp"  — the gated MLPs (weight-side,
                               #            plane-cacheable)
                               #   "attn" — the attention score/value
                               #            products Q@K^T and P@V
                               #            (activation x activation;
                               #            mode="bitexact" Booth families
                               #            only — docs/attention.md)
                               #   "all"  — both
    use_pallas: bool = False   # mode="noise": fused quant_matmul Pallas
                               # kernel (quantize->MXU->in-kernel noise->
                               # descale; interpret-mode off TPU)

    def __post_init__(self):
        if self.apply_to not in ("mlp", "attn", "all"):
            raise ValueError(f"apply_to must be 'mlp', 'attn' or 'all', "
                             f"got {self.apply_to!r}")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- MTP (deepseek) ---
    mtp_depth: int = 0
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_groups: int = 1
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0      # shared transformer block period
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500         # precomputed frame embeddings (stub)
    # --- modality frontend stub ---
    frontend: str = "none"          # none | audio | vision
    # --- paper technique ---
    amm: AmmConfig = dataclasses.field(default_factory=AmmConfig)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Archs eligible for the long_500k shape (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ArchConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 512) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    scale = d_model / cfg.d_model
    def sc(x, lo=1):
        return max(lo, int(round(x * scale)))
    heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv_heads, heads))
    return dataclasses.replace(
        cfg,
        n_layers=layers, d_model=d_model,
        n_heads=heads, n_kv_heads=kv, head_dim=d_model // heads,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab=vocab,
        n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
        moe_d_ff=2 * d_model if cfg.moe_d_ff else 0,
        first_k_dense=min(cfg.first_k_dense, 1),
        q_lora_rank=sc(cfg.q_lora_rank, 8) if cfg.q_lora_rank else 0,
        kv_lora_rank=sc(cfg.kv_lora_rank, 8) if cfg.kv_lora_rank else 0,
        qk_nope_dim=16 if cfg.qk_nope_dim else 0,
        qk_rope_dim=8 if cfg.qk_rope_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=min(cfg.ssm_state, 16), ssm_headdim=16, ssm_chunk=16,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_len=32 if cfg.is_encoder_decoder else cfg.encoder_len,
        mtp_depth=cfg.mtp_depth,
    )
