"""Assigned-architecture registry (+ the paper's FIR testbed config)."""
from .base import AmmConfig, ArchConfig, ShapeConfig, SHAPES, reduced

_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "grok-1-314b": "grok1_314b",
    "mamba2-370m": "mamba2_370m",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen2-0.5b": "qwen2_0_5b",
    "llama3.2-3b": "llama3_2_3b",
    "yi-34b": "yi_34b",
    "whisper-base": "whisper_base",
    "chameleon-34b": "chameleon_34b",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCH_NAMES = sorted(_MODULES)


def get_arch(name: str) -> ArchConfig:
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


__all__ = ["AmmConfig", "ArchConfig", "ShapeConfig", "SHAPES", "reduced",
           "ARCH_NAMES", "get_arch"]
