"""The paper's own 'architecture': the 30-tap FIR filter testbed."""
from ..core.multipliers import MulSpec

WL = 16
VBL_OPERATING = 13       # paper's chosen operating point
SPEC_ACCURATE = MulSpec("booth", WL, 0)
SPEC_APPROX = MulSpec("bbm0", WL, VBL_OPERATING)
