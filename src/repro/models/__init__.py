"""Model zoo: composable LM families with the paper's approximate-matmul
technique as a first-class layer."""
from .common import AmmRuntime, amm_dot, cross_entropy_loss
from .transformer import (ModelRuntime, init_cache, lm_amm_planes, lm_apply,
                          lm_init, lm_logical_axes, lm_loss, lm_table)

__all__ = ["AmmRuntime", "amm_dot", "cross_entropy_loss", "ModelRuntime",
           "init_cache", "lm_amm_planes", "lm_apply", "lm_init",
           "lm_logical_axes", "lm_loss", "lm_table"]
