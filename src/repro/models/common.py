"""Shared model machinery: declarative params, norms, RoPE, and the paper's
approximate-matmul (`amm`) layer.

Parameters are declared once as ``Spec`` entries (shape + logical axes +
init); both the real initializer and the dry-run's shape/sharding trees are
derived from the same table, so sharding rules can never drift from shapes.

Logical axis names (mapped to mesh axes by parallel/logical.py):
  layers, embed, heads, kv_heads, head_dim, mlp, experts, expert_mlp,
  vocab, kv_latent, q_latent, ssm_inner, ssm_state, ssm_heads, conv, batch,
  seq, scalar
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AmmConfig
from ..core.multipliers import MulSpec
from ..core.noise import make_noise_model
from ..kernels.bbm_matmul import bbm_matmul_dynamic, bbm_matmul_scaled
from ..kernels.booth_rows import booth_precode
from ..kernels.ref import (AMM_BOOTH_KINDS, amm_approx_ref,
                           amm_effective_vbl, amm_quantize)

__all__ = ["Spec", "init_params", "param_logical_axes", "rmsnorm",
           "rope_freqs", "apply_rope", "amm_dense", "amm_dot", "AmmRuntime",
           "cross_entropy_loss"]


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declaration of one parameter tensor."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | small
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(key, spec: Spec, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    scale = spec.scale if spec.init == "normal" else 1e-3
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale
            ).astype(dtype)


def init_params(table: Dict[str, Any], key, dtype=jnp.float32):
    """Materialize a (possibly nested) dict of Spec into arrays."""
    leaves, treedef = jax.tree.flatten(
        table, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def param_logical_axes(table: Dict[str, Any]):
    """The same tree with each Spec replaced by its logical axis tuple."""
    return jax.tree.map(lambda s: s.axes, table,
                        is_leaf=lambda x: isinstance(x, Spec))


# ---------------------------------------------------------------- numerics
def rmsnorm(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,s,1,d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ----------------------------------------------------- approximate matmul
@dataclasses.dataclass(frozen=True)
class AmmRuntime:
    """Resolved runtime for an AmmConfig: moments from the characterization
    cache, kept as python floats so they fold into the jaxpr."""
    cfg: AmmConfig
    mu: float = 0.0
    sigma: float = 0.0

    @staticmethod
    def build(cfg: AmmConfig) -> "AmmRuntime":
        if cfg.mode != "noise":
            return AmmRuntime(cfg)
        spec = MulSpec(cfg.mul, cfg.wl, cfg.param)
        nm = make_noise_model(spec, sample=1 << 18)
        return AmmRuntime(cfg, mu=nm.mean, sigma=float(np.sqrt(nm.var)))

    @property
    def spec(self) -> MulSpec:
        return MulSpec(self.cfg.mul, self.cfg.wl, self.cfg.param)

    @property
    def cacheable(self) -> bool:
        """Does mode="bitexact" run the precodable dot-form datapath?"""
        return (self.cfg.mode == "bitexact"
                and self.cfg.mul in AMM_BOOTH_KINDS)

    @property
    def mlp_active(self) -> bool:
        """Do the model's MLP (weight-side) matmuls route through amm?

        ``apply_to`` is the model-level router: "mlp" and "all" cover the
        gated MLPs (every mode), "attn" leaves them exact so the attention
        contribution can be measured in isolation.
        """
        return (self.cfg.mode != "off"
                and self.cfg.apply_to in ("mlp", "all"))

    @property
    def attn_active(self) -> bool:
        """Do the attention score/value products route through amm?

        ``Q @ K^T`` and ``P @ V`` multiply activations by activations —
        there is no weight side, so only the bitexact Booth-family
        datapath has a lowering for them (``amm_dot`` on
        ``kernels.bbm_matmul_dynamic``).  mode="noise" keeps attention
        exact even under apply_to="all": its moments are calibrated for
        the per-matmul quantize-then-perturb pipeline and have not been
        characterized for softmax-coupled products (docs/attention.md).
        """
        return (self.cfg.mode == "bitexact"
                and self.cfg.mul in AMM_BOOTH_KINDS
                and self.cfg.apply_to in ("attn", "all"))

    @property
    def attn_lowering(self):
        """``(wl, vbl, kind)`` of the Booth-family dot-form lowering.

        The static parameters every bitexact attention product lowers
        with — ``amm_dot``'s vmapped ``bbm_matmul_dynamic``, and the
        flash-amm kernel's in-tile correction — derived in one place so
        the two datapaths can never disagree on them.  None when the
        configured mode/family has no dot-form lowering.
        """
        kind = AMM_BOOTH_KINDS.get(self.cfg.mul)
        if kind is None or self.cfg.mode != "bitexact":
            return None
        return (self.cfg.wl, amm_effective_vbl(self.spec), kind)

    def precode(self, w):
        """Per-parameter digit-plane cache entry for one (K, N) weight.

        Weights are constant across decode steps and serving requests, so
        their dynamic quantization scale and radix-4 Booth digit planes —
        the whole decode phase of the Broken-Booth datapath — can be
        derived once per parameter and reused by every ``amm_dense`` call
        (the ``dsp.PrecodedBank`` argument, at model scale).  Returns
        ``{"mag", "neg", "s_w"}`` with planes of shape (wl//2, K, N), or
        None when the configured mode/family has nothing to cache.
        ``jax.vmap(rt.precode)`` handles layer-stacked (L, K, N) weights
        (per-layer scales, planes (L, wl//2, K, N) — scan-sliceable).
        """
        if not self.cacheable:
            return None
        wq, s_w = amm_quantize(w, self.cfg.wl)
        mag, neg = booth_precode(wq, self.cfg.wl)
        return {"mag": mag, "neg": neg, "s_w": s_w}


def _amm_bitexact_approx(x, w, rt: AmmRuntime, planes=None):
    """Forward value of mode="bitexact": the dot-form Broken-Booth matmul.

    Booth-family specs run on ``kernels.bbm_matmul_scaled``: quantize to
    int codes, contract via the folded dot form (exact ``x @ bq`` integer
    matmul + a few narrow contractions per truncated row, int32-exact in
    K-chunks), descale — bit-identical to the scalar closed forms
    (``kernels.ref.amm_dense_ref``) but O(M*N) live memory instead of the
    oracle's (..., K, N) product grid, so it serves real model shapes.
    Non-Booth families (bam/kulkarni/etm) have no dot lowering and keep
    the scalar oracle path (reduced configs only, as before).

    ``planes``: optional ``AmmRuntime.precode(w)`` cache entry — skips
    the per-call weight quantization + digit decode; bit-identical to the
    uncached path.
    """
    cfg = rt.cfg
    kind = AMM_BOOTH_KINDS.get(cfg.mul)
    if kind is None:
        return amm_approx_ref(x, w, rt.spec)
    wl = cfg.wl
    vbl = amm_effective_vbl(rt.spec)
    xq, s_x = amm_quantize(x, wl)
    if planes is None:
        planes = rt.precode(w)
    s_w = planes["s_w"]
    yq = bbm_matmul_scaled(xq.reshape(-1, x.shape[-1]), planes["mag"],
                           planes["neg"], wl=wl, vbl=vbl, kind=kind)
    yq = yq.reshape(x.shape[:-1] + (w.shape[-1],))
    return (yq * (s_x * s_w)).astype(x.dtype)


def amm_dense(x, w, rt: AmmRuntime, key=None, planes=None):
    """Matmul over the last axis of x with the paper's technique applied.

    Straight-through estimator: gradients flow through the exact product;
    the forward value carries the quantization + approximate-multiplier
    error.  x: (..., K), w: (K, N).

    planes: optional per-parameter cache from ``AmmRuntime.precode(w)``
    (mode="bitexact" only) — the weight-side decode phase hoisted out of
    the hot loop; bit-identical with or without.
    """
    cfg = rt.cfg
    exact = x @ w
    if cfg.mode == "off":
        return exact
    if cfg.mode == "noise":
        # one quantizer for both amm modes (kernels.ref.amm_quantize):
        # the noise and bitexact columns of lm_quality must sit on the
        # same code grid or their gap stops measuring the noise model.
        # XLA dead-code-eliminates the unused codes on the pallas branch
        # (the kernel quantizes in-tile from the same scales).
        xq_i, s_x = amm_quantize(x, cfg.wl)
        wq_i, s_w = amm_quantize(w, cfg.wl)
        if cfg.use_pallas:
            # fused Pallas path: quantize -> matmul -> in-kernel hash
            # noise -> descale, one pass over VMEM tiles (interpret-mode
            # off TPU).  Seeded from `key` so draws differ across steps.
            from ..kernels.ops import quant_matmul
            seed = (jnp.int32(0) if key is None
                    else jax.random.randint(key, (), 0, 2 ** 31 - 1,
                                            jnp.int32))
            # the kernel has no JVP rule and needs none: the STE routes
            # every gradient through `exact`, so cut the tangents at the
            # kernel's operands instead of after its output
            sg = jax.lax.stop_gradient
            yq = quant_matmul(
                sg(x.reshape(-1, x.shape[-1]).astype(jnp.float32)),
                sg(w.astype(jnp.float32)), s_x, s_w,
                rt.mu if key is not None else 0.0,
                rt.sigma if key is not None else 0.0,
                wl=cfg.wl, seed=seed)
            approx = yq.reshape(x.shape[:-1] + (w.shape[-1],)).astype(x.dtype)
            return exact + jax.lax.stop_gradient(approx - exact)
        yq = xq_i.astype(jnp.float32) @ wq_i.astype(jnp.float32)
        k_len = x.shape[-1]
        if key is not None and (rt.mu != 0.0 or rt.sigma != 0.0):
            z = jax.random.normal(key, yq.shape, jnp.float32)
            yq = yq + rt.mu * k_len + rt.sigma * (k_len ** 0.5) * z
        approx = (yq * (s_x * s_w)).astype(x.dtype)
        return exact + jax.lax.stop_gradient(approx - exact)
    if cfg.mode == "bitexact":
        approx = _amm_bitexact_approx(x, w, rt, planes=planes)
        return exact + jax.lax.stop_gradient(approx - exact)
    raise ValueError(f"unknown amm mode {cfg.mode!r}")


def amm_dot(a, b, rt: AmmRuntime, *, oracle: bool = False, ste: bool = True):
    """Both-operands-dynamic approximate matmul — the attention-side
    ``amm_dense``.

    Contracts the trailing axis of ``a`` against the second-to-last axis
    of ``b``, batched over their (matching) leading axes: the shape of the
    attention score product ``Q @ K^T`` and value product ``P @ V``.
    Neither operand is a parameter, so there is nothing to precode or
    cache — both sides are quantized per call, and the vmap over the
    leading (batch, head) axes gives every slice its own pair of dynamic
    scales (per-block quantization; docs/attention.md).

    Straight-through like ``amm_dense``: gradients flow through the exact
    batched matmul, the forward value carries the Broken-Booth error.
    Only the bitexact Booth-family datapath has a lowering here; callers
    gate on ``AmmRuntime.attn_active`` (the guard below is defensive and
    returns the exact product).

    oracle=True forms every product through the scalar closed forms
    (``kernels.ref.amm_dot_ref``) instead of the dot-form contraction —
    bit-identical by the amm contract.  ``kernels.ref.amm_attention_ref``
    uses it to oracle the attention datapath while sharing the softmax
    schedule.

    ste=False skips the straight-through composition and returns the raw
    approximate product.  ``exact + (approx - exact)`` is not bitwise
    ``approx`` in float32, so inference paths that must match the pure
    code-domain datapath (the int-code KV cache, whose decode never forms
    an exact product at all) need the uncomposed value.
    """
    lowering = rt.attn_lowering
    if lowering is None:
        return a @ b
    if oracle:
        from ..kernels.ref import amm_dot_ref
        approx = amm_dot_ref(a, b, rt.spec)
    else:
        wl, vbl, kind = lowering
        fn = partial(bbm_matmul_dynamic, wl=wl, vbl=vbl, kind=kind)
        for _ in range(a.ndim - 2):
            fn = jax.vmap(fn)
        approx = fn(a, b)
    if not ste:
        return approx
    exact = a @ b
    return exact + jax.lax.stop_gradient(approx - exact)


# ------------------------------------------------------------------- loss
def cross_entropy_loss(logits, labels, *, z_loss: float = 1e-4):
    """Mean token cross entropy (fp32 logsumexp) + optional z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
