"""Shared model machinery: declarative params, norms, RoPE, and the paper's
approximate-matmul (`amm`) layer.

Parameters are declared once as ``Spec`` entries (shape + logical axes +
init); both the real initializer and the dry-run's shape/sharding trees are
derived from the same table, so sharding rules can never drift from shapes.

Logical axis names (mapped to mesh axes by parallel/logical.py):
  layers, embed, heads, kv_heads, head_dim, mlp, experts, expert_mlp,
  vocab, kv_latent, q_latent, ssm_inner, ssm_state, ssm_heads, conv, batch,
  seq, scalar
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AmmConfig
from ..core.multipliers import MulSpec, mul as core_mul
from ..core.noise import make_noise_model

__all__ = ["Spec", "init_params", "param_logical_axes", "rmsnorm",
           "rope_freqs", "apply_rope", "amm_dense", "AmmRuntime",
           "cross_entropy_loss"]


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declaration of one parameter tensor."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | small
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(key, spec: Spec, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    scale = spec.scale if spec.init == "normal" else 1e-3
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale
            ).astype(dtype)


def init_params(table: Dict[str, Any], key, dtype=jnp.float32):
    """Materialize a (possibly nested) dict of Spec into arrays."""
    leaves, treedef = jax.tree.flatten(
        table, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def param_logical_axes(table: Dict[str, Any]):
    """The same tree with each Spec replaced by its logical axis tuple."""
    return jax.tree.map(lambda s: s.axes, table,
                        is_leaf=lambda x: isinstance(x, Spec))


# ---------------------------------------------------------------- numerics
def rmsnorm(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,s,1,d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ----------------------------------------------------- approximate matmul
@dataclasses.dataclass(frozen=True)
class AmmRuntime:
    """Resolved runtime for an AmmConfig: moments from the characterization
    cache, kept as python floats so they fold into the jaxpr."""
    cfg: AmmConfig
    mu: float = 0.0
    sigma: float = 0.0

    @staticmethod
    def build(cfg: AmmConfig) -> "AmmRuntime":
        if cfg.mode != "noise":
            return AmmRuntime(cfg)
        spec = MulSpec(cfg.mul, cfg.wl, cfg.param)
        nm = make_noise_model(spec, sample=1 << 18)
        return AmmRuntime(cfg, mu=nm.mean, sigma=float(np.sqrt(nm.var)))


def _dyn_scale(x, wl: int):
    lim = float(2 ** (wl - 1) - 1)
    s = jnp.max(jnp.abs(x)) / lim
    return jax.lax.stop_gradient(jnp.maximum(s, 1e-12))


def amm_dense(x, w, rt: AmmRuntime, key=None):
    """Matmul over the last axis of x with the paper's technique applied.

    Straight-through estimator: gradients flow through the exact product;
    the forward value carries the quantization + approximate-multiplier
    error.  x: (..., K), w: (K, N).
    """
    cfg = rt.cfg
    exact = x @ w
    if cfg.mode == "off":
        return exact
    if cfg.mode == "noise":
        s_x = _dyn_scale(x, cfg.wl)
        s_w = _dyn_scale(w, cfg.wl)
        lim = float(2 ** (cfg.wl - 1) - 1)
        xq = jnp.round(jnp.clip(x / s_x, -lim - 1, lim)).astype(jnp.float32)
        wq = jnp.round(jnp.clip(w / s_w, -lim - 1, lim)).astype(jnp.float32)
        yq = xq @ wq
        k_len = x.shape[-1]
        if key is not None and (rt.mu != 0.0 or rt.sigma != 0.0):
            z = jax.random.normal(key, yq.shape, jnp.float32)
            yq = yq + rt.mu * k_len + rt.sigma * (k_len ** 0.5) * z
        approx = (yq * (s_x * s_w)).astype(x.dtype)
        return exact + jax.lax.stop_gradient(approx - exact)
    if cfg.mode == "bitexact":
        spec = MulSpec(cfg.mul, cfg.wl, cfg.param)
        s_x = _dyn_scale(x, cfg.wl)
        s_w = _dyn_scale(w, cfg.wl)
        lim = 2 ** (cfg.wl - 1) - 1
        xq = jnp.clip(jnp.round(x / s_x), -lim - 1, lim).astype(jnp.int32)
        wq = jnp.clip(jnp.round(w / s_w), -lim - 1, lim).astype(jnp.int32)
        prod = core_mul(spec)(xq[..., :, None], wq[None, :, :])
        yq = jnp.sum(prod.astype(jnp.float32), axis=-2)
        approx = (yq * (s_x * s_w)).astype(x.dtype)
        return exact + jax.lax.stop_gradient(approx - exact)
    raise ValueError(f"unknown amm mode {cfg.mode!r}")


# ------------------------------------------------------------------- loss
def cross_entropy_loss(logits, labels, *, z_loss: float = 1e-4):
    """Mean token cross entropy (fp32 logsumexp) + optional z-loss."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
