"""Attention blocks: GQA (llama/qwen/grok/yi/chameleon/zamba/whisper) and
MLA (deepseek-v3), with chunked online-softmax attention for long context.

The chunked path is pure JAX (lax.scan over query and KV blocks) so it
lowers on any backend — it is what the 512-device dry-run compiles.  On TPU
the Pallas flash kernel (kernels/flash_attention.py) is selected via
``use_pallas`` (numerics validated equal in tests).

Approximate attention (``amm=``): the score product ``Q @ K^T`` and the
value product ``P @ V`` can route through the bit-exact Broken-Booth
dot-form datapath (``models.common.amm_dot`` on
``kernels.bbm_matmul_dynamic``) — the activation x activation counterpart
of the MLPs' ``amm_dense``.  Both products are formed *per KV block*, each
block's integer accumulation completing before any online-softmax
renormalization touches its result, so the softmax algebra composes
unchanged (docs/attention.md carries the envelope argument).

Routing (prefill, no cache) — ``use_pallas`` picks the flash lowering for
both exact *and* amm attention:
  * exact-flash:  ``use_pallas``, ``amm`` inactive — the Pallas kernel in
    kernels/flash_attention.py.
  * flash-amm:    ``use_pallas``, ``amm`` active with a Booth-family
    bitexact lowering — ``kernels.flash_attention.flash_attention_amm``
    (Pallas kernel on TPU, fused XLA scan elsewhere), wrapped in a
    ``custom_vjp`` whose backward is the chunked path's STE gradient.
  * chunked-amm / chunked-exact: everything else — the pure-JAX path
    below, which is also the flash-amm bit-equality reference
    (``flash_amm_chunked_equiv``) and the oracle-comparison path.
Falling off the flash path while ``use_pallas`` was requested (sequence
cap, amm family without a lowering) emits a ``FlashFallbackWarning``
naming the reason, so long-context runs can tell why they landed on the
chunked path.

KV caches are ``(batch, seq, kv_heads, head_dim)`` per tensor (MLA caches the
compressed latent ``(batch, seq, kv_latent+rope)``), updated with
``dynamic_update_slice`` at the decode position — a scalar, or a (B,)
per-slot vector under continuous batching.  The serving-side int-code
variant (``serve.kv_cache``) stores wl-bit codes plus per-block f32 scales
instead of float values; ``code_cache_update`` freezes each token's codes
at write time and ``decode_attention_codes`` contracts them directly
(docs/serving.md).
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import Spec, amm_dot, apply_rope, rmsnorm

__all__ = ["attn_table", "mla_table", "attention", "mla_attention",
           "chunked_attention", "code_cache_dequant", "code_cache_update",
           "decode_attention", "decode_attention_codes",
           "flash_amm_chunked_equiv", "FlashFallbackWarning",
           "reset_flash_fallback_dedup"]

NEG_INF = -1e30

# flash-path sequence cap: above this the kernel's (batch*heads, S, D)
# operand working set outgrows the tested envelope and the chunked path is
# selected instead.  Module-level so tests (and long-context experiments)
# can lower it to exercise the fallback warning.
_FLASH_SEQ_CAP = 32768


class FlashFallbackWarning(UserWarning):
    """A ``use_pallas`` attention call fell back to the chunked path."""


# (reason, caller file, caller line) triples that already warned: a decode
# loop hitting the same fallback every step (or every retrace) says it
# once, not once per token — repetition adds noise, not information
_seen_fallbacks: set = set()


def reset_flash_fallback_dedup() -> None:
    """Forget which fallback sites have warned (tests, a new serving run)."""
    _seen_fallbacks.clear()


def _flash_fallback(reason: str, **ctx):
    import sys
    f = sys._getframe(2)     # the user call site stacklevel=3 attributes to
    site = (reason, f.f_code.co_filename, f.f_lineno)
    if site in _seen_fallbacks:
        return
    _seen_fallbacks.add(site)
    detail = ", ".join(f"{k}={v}" for k, v in ctx.items())
    warnings.warn(FlashFallbackWarning(
        f"use_pallas requested but attention fell back to the chunked "
        f"path: {reason} ({detail})"), stacklevel=3)


def _maybe_constrain(x, *axes):
    """with_sharding_constraint that degrades to a no-op when no mesh is
    in context (single-host tests); the dry-run lowers under `with mesh:`."""
    from jax.sharding import PartitionSpec as _P
    try:
        return jax.lax.with_sharding_constraint(x, _P(*axes))
    except (RuntimeError, ValueError):
        return x


# --------------------------------------------------------------- parameters
def attn_table(cfg: ArchConfig) -> Dict[str, Spec]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    t = {
        "wq": Spec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Spec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Spec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = Spec((h, hd), ("heads", "head_dim"), "zeros")
        t["bk"] = Spec((kv, hd), ("kv_heads", "head_dim"), "zeros")
        t["bv"] = Spec((kv, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        t["q_norm"] = Spec((hd,), ("head_dim",), "ones")
        t["k_norm"] = Spec((hd,), ("head_dim",), "ones")
    return t


def mla_table(cfg: ArchConfig) -> Dict[str, Spec]:
    d, h = cfg.d_model, cfg.n_heads
    qk_n, qk_r, v_hd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": Spec((d, cfg.q_lora_rank), ("embed", "q_latent")),
        "q_a_norm": Spec((cfg.q_lora_rank,), ("q_latent",), "ones"),
        "wq_b": Spec((cfg.q_lora_rank, h, qk_n + qk_r),
                     ("q_latent", "heads", "head_dim")),
        "w_dkv": Spec((d, cfg.kv_lora_rank + qk_r), ("embed", "kv_latent")),
        "kv_norm": Spec((cfg.kv_lora_rank,), ("kv_latent",), "ones"),
        "w_uk": Spec((cfg.kv_lora_rank, h, qk_n),
                     ("kv_latent", "heads", "head_dim")),
        "w_uv": Spec((cfg.kv_lora_rank, h, v_hd),
                     ("kv_latent", "heads", "head_dim")),
        "wo": Spec((h, v_hd, d), ("heads", "head_dim", "embed")),
    }


# ----------------------------------------------------------- core attention
def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      bq: int = 512, bk: int = 1024, kv_len=None,
                      remat_qblock: bool = False,
                      causal_skip: bool = False,
                      p_bf16: bool = False,
                      amm=None, amm_oracle: bool = False):
    """Online-softmax blockwise attention, pure JAX.

    q: (B, Sq, H, D), k/v: (B, Skv, KV, D) with H a multiple of KV (GQA).
    q_offset: global position of q[0] (for causal masking vs. a cache).
    kv_len: number of valid kv positions (<= Skv), static or traced.
    remat_qblock: checkpoint each q-block so the backward pass recomputes
      the (bq x bk) score blocks instead of saving them through the KV scan
      (flash-attention-style backward; see docs/perf.md §Model-side perf
      levers — the saved score residuals are the dominant memory term of
      the baseline).
    causal_skip: unroll the q-block loop in python so each q block scans
      only its own past KV blocks — halves attention FLOPs and score
      traffic vs. the masked full grid.  Needs causal, static q_offset == 0
      and modest nq (HLO grows linearly in nq); falls back otherwise.
    amm: optional ``AmmRuntime`` — form the per-block score and value
      products through the approximate datapath (``common.amm_dot``; the
      caller gates on ``AmmRuntime.attn_active``).  ``p_bf16`` is ignored
      on that path: the amm product owns its own quantization.
    amm_oracle: with ``amm``, form the products through the scalar closed
      forms instead of the dot-form contraction — the hook
      ``kernels.ref.amm_attention_ref`` uses to oracle this schedule.
    Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    dv = v.shape[-1]
    groups = h // kvh
    bq = min(bq, sq)
    bk = min(bk, skv)
    nq, nk = -(-sq // bq), -(-skv // bk)
    pad_q = nq * bq - sq
    pad_k = nk * bk - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if kv_len is None:
        kv_len = skv
    # (B, nq, bq, H, D) -> scan over nq
    qb = q.reshape(b, nq, bq, h, d).transpose(1, 0, 3, 2, 4)   # (nq,B,H,bq,D)
    kb = k.reshape(b, nk, bk, kvh, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, bk, kvh, dv).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / (d ** 0.5)

    def q_block(qi, q_i, kb_s, vb_s, n_blocks):
        q_i = q_i.astype(jnp.float32) * scale               # (B,H,bq,D)
        qg = q_i.reshape(b, kvh, groups * bq, d)            # group fold

        def kv_block(carry, inp):
            ki, k_j, v_j = inp
            m, l, acc = carry
            if amm is not None:
                # the Broken-Booth score product: one both-sides-dynamic
                # approximate matmul per (batch, kv-head) slice
                s = amm_dot(qg, k_j.astype(jnp.float32).swapaxes(-1, -2),
                            amm, oracle=amm_oracle)          # (B,KV,g*bq,bk)
            else:
                s = jnp.einsum("bgqd,bgkd->bgqk", qg,
                               k_j.astype(jnp.float32))     # (B,KV,g*bq,bk)
            s4 = s.reshape(b, kvh, groups, bq, bk)
            qpos = q_offset + qi * bq + jnp.arange(bq)
            kpos = ki * bk + jnp.arange(bk)
            live = (kpos < kv_len)[None, :]
            if causal:
                live = live & (qpos[:, None] >= kpos[None, :])
            s4 = jnp.where(live[None, None, None], s4, NEG_INF)
            s = s4.reshape(b, kvh, groups * bq, bk)
            m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1, keepdims=True)
            if amm is not None:
                # the Broken-Booth value product; p's block rows are the
                # finished (un-normalized) probabilities, quantized per
                # (batch, kv-head) slice like the scores
                pv = amm_dot(p, v_j.astype(jnp.float32), amm,
                             oracle=amm_oracle)
            elif p_bf16:
                # halve the probability-block HBM traffic; the f32 psum of
                # l_new keeps the normalizer exact (docs/perf.md
                # §Model-side perf levers)
                pv = jnp.einsum("bgqk,bgkd->bgqd", p.astype(jnp.bfloat16),
                                v_j.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bgqk,bgkd->bgqd", p,
                                v_j.astype(jnp.float32))
            acc_new = acc * alpha + pv
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kvh, groups * bq, 1), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, groups * bq, 1), jnp.float32),
                jnp.zeros((b, kvh, groups * bq, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, init, (jnp.arange(n_blocks), kb_s, vb_s))
        out = acc / jnp.maximum(l, 1e-30)
        return out.reshape(b, kvh, groups, bq, dv).reshape(b, h, bq, dv)

    use_skip = (causal_skip and causal and isinstance(q_offset, int)
                and q_offset == 0 and nq <= 16)
    if use_skip:
        # python-unrolled q blocks, each scanning only its past KV blocks
        def one(qi, q_i):
            n_blocks = min(-(-((qi + 1) * bq) // bk), nk)
            return q_block(qi, q_i, kb[:n_blocks], vb[:n_blocks], n_blocks)
        fn = jax.checkpoint(one, static_argnums=(0,)) if remat_qblock else one
        outs = jnp.stack([fn(qi, qb[qi]) for qi in range(nq)])
    else:
        def block_fn(qi, q_i):
            return q_block(qi, q_i, kb, vb, nk)
        if remat_qblock:
            block_fn = jax.checkpoint(block_fn)
        outs = jax.lax.map(lambda args: block_fn(*args),
                           (jnp.arange(nq), qb))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nq * bq, h, dv)
    return out[:, :sq].astype(q.dtype)


def flash_amm_chunked_equiv(q, k, v, amm, *, causal: bool = True):
    """The chunked-amm run that flash-amm is bit-identical to.

    (B, H, S, D) operands with matched head counts, exactly as
    ``flash_attention_amm`` takes them.  Quantization is per block, so the
    equality contract needs the chunked schedule at the flash tile sizes —
    this wrapper pins them (``FLASH_AMM_BQ``/``FLASH_AMM_BK``) and is both
    the test reference and the backward function of the flash-amm
    ``custom_vjp`` (the chunked path's straight-through gradient *is* the
    flash-amm gradient).
    """
    from ..kernels.flash_attention import FLASH_AMM_BK, FLASH_AMM_BQ
    out = chunked_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal,
                            bq=FLASH_AMM_BQ, bk=FLASH_AMM_BK, amm=amm)
    return out.transpose(0, 2, 1, 3)


def _flash_amm_impl(amm, causal, q, k, v):
    from ..kernels.flash_attention import flash_attention_amm
    wl, vbl, kind = amm.attn_lowering
    return flash_attention_amm(q, k, v, wl=wl, vbl=vbl, kind=kind,
                               causal=causal)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _flash_amm_ste(amm, causal, q, k, v):
    """Flash-amm forward with the chunked path's STE gradient.

    The kernel composes ``exact + stop_gradient(approx - exact)`` per
    tile, but differentiating *through* a Pallas call is not supported —
    so the backward runs ``jax.vjp`` of the bit-identical chunked
    schedule instead, which routes every gradient through the exact
    products (the same straight-through rule ``amm_dot`` implements).
    """
    return _flash_amm_impl(amm, causal, q, k, v)


def _flash_amm_fwd(amm, causal, q, k, v):
    return _flash_amm_impl(amm, causal, q, k, v), (q, k, v)


def _flash_amm_bwd(amm, causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda qq, kk, vv: flash_amm_chunked_equiv(
        qq, kk, vv, amm, causal=causal), q, k, v)
    return vjp(g)


_flash_amm_ste.defvjp(_flash_amm_fwd, _flash_amm_bwd)


def decode_attention(q, k_cache, v_cache, kv_len, *, amm=None,
                     amm_oracle: bool = False, amm_ste: bool = True):
    """Single-position attention against a float cache (requantize-per-call).

    q: (B, 1, H, D); caches: (B, S, KV, D); kv_len: valid length — a
    traced scalar, or a (B,) per-slot vector under continuous batching.
    amm/amm_oracle: as in ``chunked_attention``; ``amm_ste=False`` returns
    the pure approximate forward (no straight-through composition — see
    ``amm_dot``).

    The amm products are quantized per (batch, kv-head) over the *whole*
    cache slice on every call.  Two consequences the int-code cache path
    (``decode_attention_codes``) exists to remove: every decode step pays
    the K/V-side max/round/clip requantize pass, and a token's quantized
    representation is a function of everything else in the slice — an
    envelope-edge arrival *later* in the sequence (or garbage in a reused
    slot past ``kv_len``, which the NEG_INF mask hides from the softmax
    but not from the dynamic-range scale) moves the shared scale and
    silently re-rounds every earlier token's codes.  Frozen-at-write codes
    make each token's bits independent of later arrivals;
    tests/test_amm_attention.py pins the drift this path allows.
    """
    b, _, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    dv = v_cache.shape[-1]
    groups = h // kvh
    qf = q.astype(jnp.float32).reshape(b, kvh, groups, d) / (d ** 0.5)
    if amm is not None:
        sc = amm_dot(qf, k_cache.astype(jnp.float32).transpose(0, 2, 3, 1),
                     amm, oracle=amm_oracle, ste=amm_ste)   # (B,KV,g,S)
    else:
        sc = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    kvl = jnp.asarray(kv_len)
    if kvl.ndim == 1:
        kvl = kvl[:, None, None, None]
    live = jnp.arange(s)[None, None, None, :] < kvl
    sc = jnp.where(live, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    if amm is not None:
        out = amm_dot(p, v_cache.astype(jnp.float32).transpose(0, 2, 1, 3),
                      amm, oracle=amm_oracle, ste=amm_ste)  # (B,KV,g,Dv)
    else:
        out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, dv).astype(q.dtype)


# ------------------------------------------------------- int-code KV cache
def _code_write_slot(codes, scales, vf, p, *, lim: int, block: int):
    """Single-slot quantized cache write with first-touch block scales.

    codes: (S, KV, hd) int codes; scales: (nb, KV) f32, 0.0 marking a
    never-written block (``amm_quantize`` scales are floored at 1e-12, so
    0.0 is unreachable as a real scale); vf: (s, KV, hd) f32 rows to
    write at position ``p``.  The first write touching a block fixes its
    per-kv-head scale from that write's dynamic range — exactly the
    ``amm_quantize`` scale expression, per head — and every later write
    into the block quantizes (and clips) against the frozen scale, so a
    token's codes never change after they are written.
    """
    s_new = vf.shape[0]
    nb = scales.shape[0]
    n_touch = -(-s_new // block) + 1     # worst-case block-misaligned span
    b0 = p // block
    blk_scales = []
    for t in range(n_touch):
        bi = b0 + t
        rel = bi * block - p + jnp.arange(block)   # block rows -> vf rows
        m = (rel >= 0) & (rel < s_new)
        vals = jnp.abs(vf[jnp.clip(rel, 0, s_new - 1)]) * m[:, None, None]
        cand = jnp.maximum(jnp.max(vals, axis=(0, 2)) * (1.0 / lim), 1e-12)
        bic = jnp.clip(bi, 0, nb - 1)
        old = jax.lax.dynamic_slice_in_dim(scales, bic, 1, axis=0)[0]
        sc = jnp.where(old > 0.0, old, cand)
        keep = m.any() & (bi < nb)
        scales = jax.lax.dynamic_update_slice_in_dim(
            scales, jnp.where(keep, sc, old)[None], bic, axis=0)
        blk_scales.append(sc)
    per_blk = jnp.stack(blk_scales)                       # (n_touch, KV)
    tok_blk = (p + jnp.arange(s_new)) // block - b0
    sc_tok = per_blk[tok_blk]                             # (s, KV)
    q = jnp.clip(jnp.round(vf / sc_tok[..., None]), -lim - 1, lim)
    codes = jax.lax.dynamic_update_slice(
        codes, q.astype(codes.dtype), (p,) + (0,) * (codes.ndim - 1))
    return codes, scales


def code_cache_update(codes, scales, x, pos, *, wl: int):
    """Write new K/V rows into an int-code cache leaf as frozen codes.

    codes: (B, S, KV, hd); scales: (B, nb, KV) f32 with nb * block == S;
    x: (B, s, KV, hd) float rows; pos: scalar or (B,) per-slot positions.
    Returns (codes, scales) updated.  Scale candidates use the
    ``kernels.ref.amm_quantize`` expression per (block, kv-head) — on a
    block's first one-shot write the frozen scale is bit-identical to the
    scale the requantize-per-call path would derive for the same values,
    which is what makes the code-domain decode testable by
    ``assert_array_equal`` rather than allclose.
    """
    lim = 2 ** (wl - 1) - 1
    block = codes.shape[1] // scales.shape[1]
    vf = jnp.asarray(x, jnp.float32)
    p = jnp.asarray(pos, jnp.int32)
    fn = partial(_code_write_slot, lim=lim, block=block)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0 if p.ndim else None))(
        codes, scales, vf, p)


def code_cache_dequant(codes, scales, kv_len=None):
    """Expand an int-code cache leaf back to float32 values.

    codes: (B, S, KV, hd); scales: (B, nb, KV).  Positions past ``kv_len``
    (scalar or (B,)) are zeroed — a reused slot may hold stale codes in a
    block whose scale is already frozen, and downstream consumers assume
    dead cache rows are zeros.
    """
    b, s = codes.shape[0], codes.shape[1]
    block = s // scales.shape[1]
    sc = jnp.repeat(scales, block, axis=1)                # (B, S, KV)
    out = codes.astype(jnp.float32) * sc[..., None]
    if kv_len is not None:
        kvl = jnp.broadcast_to(
            jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
        live = jnp.arange(s)[None, :] < kvl[:, None]
        out = jnp.where(live[:, :, None, None], out, 0.0)
    return out


def decode_attention_codes(q, cache, kv_len, *, amm, amm_oracle: bool = False):
    """Single-position attention straight from the int-code KV cache.

    q: (B, 1, H, D); cache: per-layer slice of the code cache —
    ``{"k_codes", "k_scale", "v_codes", "v_scale"}`` leaves shaped as in
    ``code_cache_update``.  kv_len: scalar or (B,) per-slot lengths.

    Cached codes feed ``kernels.bbm_matmul.bbm_matmul_coded`` directly
    (per-column K scales expanded from the per-block grid; per-K-block V
    descale via the kblocks variant), skipping the per-call K/V-side
    requantize of ``bbm_matmul_dynamic``.  Only ``q`` and the softmax
    probabilities are quantized per call.  The forward value is the pure
    approximate product (no straight-through composition — at decode time
    no exact-valued K/V exists to compose against), i.e. the faithful
    serving semantics of hardware with no exact multiplier.

    Codes past ``kv_len`` are zeroed before the contraction: the NEG_INF
    score mask forces their softmax weights to exactly 0.0 (hence p-codes
    of 0), but ``bbm_type1(0, w) != 0`` for negative-row ``w``, so stale
    V codes in a reused slot would otherwise leak into the PV product.
    Zero codes contribute exactly nothing under both truncation kinds.

    amm_oracle=True forms every product through the scalar closed forms
    (``kernels.ref.amm_coded_ref`` / ``amm_coded_kblocks_ref``) on the
    same schedule — bit-identical by the codes-in amm contract.
    """
    if amm is None or not amm.attn_active or amm.attn_lowering is None:
        raise ValueError("int-code KV cache decode requires an active "
                         "Booth-family bitexact amm attention lowering "
                         "(mode='bitexact', Booth-family mul, apply_to "
                         "'attn' or 'all')")
    wl, vbl, kind = amm.attn_lowering
    kc, vc = cache["k_codes"], cache["v_codes"]
    ks, vs = cache["k_scale"], cache["v_scale"]
    b, s, kvh, d = kc.shape
    dv = vc.shape[-1]
    block = s // ks.shape[1]
    h = q.shape[2]
    groups = h // kvh
    qf = q.astype(jnp.float32).reshape(b, kvh, groups, d) / (d ** 0.5)
    kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    if amm_oracle:
        from ..kernels.ref import amm_coded_kblocks_ref, amm_coded_ref
        spec = amm.spec
        qk_fn = lambda a, c, sc: amm_coded_ref(a, c, sc, spec)
        pv_fn = lambda a, c, sc: amm_coded_kblocks_ref(a, c, sc, spec,
                                                       block=block)
    else:
        from ..kernels.bbm_matmul import (bbm_matmul_coded,
                                          bbm_matmul_coded_kblocks)
        qk_fn = partial(bbm_matmul_coded, wl=wl, vbl=vbl, kind=kind)
        pv_fn = partial(bbm_matmul_coded_kblocks, wl=wl, vbl=vbl, kind=kind,
                        block=block)

    def head_slice(qs, kT, ksl, vcs, vsl, n):
        # qs (g, d) f32; kT (d, S) codes; ksl (nb,); vcs (S, dv); vsl (nb,)
        live = jnp.arange(s) < n
        sc = qk_fn(qs, jnp.where(live[None, :], kT, 0),
                   jnp.repeat(ksl, block))
        sc = jnp.where(live[None, :], sc, NEG_INF)
        pr = jax.nn.softmax(sc, axis=-1)
        return pv_fn(pr, jnp.where(live[:, None], vcs, 0), vsl)

    fn = jax.vmap(jax.vmap(head_slice, in_axes=(0, 0, 0, 0, 0, None)),
                  in_axes=(0, 0, 0, 0, 0, 0))
    out = fn(qf,
             kc.transpose(0, 2, 3, 1).astype(jnp.int32),
             ks.transpose(0, 2, 1),
             vc.transpose(0, 2, 1, 3).astype(jnp.int32),
             vs.transpose(0, 2, 1),
             kvl)                                         # (B, KV, g, Dv)
    return out.reshape(b, 1, h, dv).astype(q.dtype)


def _cache_put(buf, new, pos):
    """dynamic_update_slice at the decode position(s).

    A scalar ``pos`` is the classic single-front write; a (B,) vector
    (continuous batching: every slot at its own depth) vmaps the update
    over the leading batch axis.
    """
    p = jnp.asarray(pos, jnp.int32)
    if p.ndim == 0:
        return jax.lax.dynamic_update_slice(
            buf, new, (0, p) + (0,) * (buf.ndim - 2))
    return jax.vmap(lambda c, n_, q_: jax.lax.dynamic_update_slice(
        c, n_, (q_,) + (0,) * (c.ndim - 1)))(buf, new, p)


# ------------------------------------------------------------ GQA attention
class KVUpdate(NamedTuple):
    k: jnp.ndarray
    v: jnp.ndarray


def attention(p, x, cfg: ArchConfig, *, positions, cache=None, pos=None,
              causal: bool = True, kv=None, use_pallas: bool = False,
              remat_qblock: bool = False, shard_heads: bool = False,
              causal_skip: bool = False, p_bf16: bool = False, amm=None):
    """GQA attention.  x: (B, S, d_model).

    cache: optional dict {"k","v"} (B, S_max, KV, D) for decode; ``pos`` is
    the current decode position (traced scalar).  kv: optional externally
    provided (k, v) (cross-attention).  amm: optional ``AmmRuntime`` — the
    score/value products go through the approximate datapath (the Q/K/V/O
    projections stay exact; docs/attention.md).  ``use_pallas`` selects
    the flash lowering for exact *and* amm-active prefill (exact-flash /
    flash-amm; the module docstring has the routing table); calls that
    fall off it — sequence beyond ``_FLASH_SEQ_CAP``, an amm family with
    no dot-form lowering, cache-backed prefill — take the chunked path,
    with a ``FlashFallbackWarning`` when ``use_pallas`` was requested.
    GQA note: the flash lowerings repeat KV heads before quantizing, so
    their per-block scales are per *repeated* head; the chunked path
    group-folds and scales per KV head.  Both are valid amm schedules —
    the bit-equality contract is defined at matched head counts
    (``flash_amm_chunked_equiv``).  Returns (out, new_cache).
    """
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    else:
        k, v = kv
    if cfg.qkv_bias:
        q = q + p["bq"]
        if kv is None:
            k = k + p["bk"]
            v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    if kv is None:
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and s > 1 and jnp.ndim(pos) == 1:
        raise ValueError("multi-token prefill needs a scalar position; "
                         "per-slot position vectors are decode-only")
    if cache is not None and "k_codes" in cache:
        # int-code KV cache: quantize at write (frozen codes + first-touch
        # block scales), decode straight from codes; prefill dequantizes
        # once and rides the standard chunked schedule
        if amm is None or amm.attn_lowering is None:
            raise ValueError("int-code KV cache requires an active "
                             "Booth-family bitexact amm attention lowering")
        wl = amm.attn_lowering[0]
        ck, sk = code_cache_update(cache["k_codes"], cache["k_scale"], k,
                                   pos, wl=wl)
        cv, sv = code_cache_update(cache["v_codes"], cache["v_scale"], v,
                                   pos, wl=wl)
        new_cache = {"k_codes": ck, "k_scale": sk,
                     "v_codes": cv, "v_scale": sv}
        if s == 1:
            out = decode_attention_codes(q, new_cache, kv_len=pos + s,
                                         amm=amm)
        else:
            kk = code_cache_dequant(ck, sk, kv_len=pos + s)
            vv = code_cache_dequant(cv, sv, kv_len=pos + s)
            out = chunked_attention(q, kk, vv, causal=causal, q_offset=pos,
                                    kv_len=pos + s,
                                    remat_qblock=remat_qblock, amm=amm)
    elif cache is not None:
        ck = _cache_put(cache["k"], k.astype(cache["k"].dtype), pos)
        cv = _cache_put(cache["v"], v.astype(cache["v"].dtype), pos)
        new_cache = {"k": ck, "v": cv}
        if s == 1:
            out = decode_attention(q, ck, cv, kv_len=pos + s, amm=amm)
        else:  # multi-token prefill against the cache
            kk, vv = ck, cv
            if shard_heads and ck.shape[2] < q.shape[2]:
                # same head-sharding trick as the train path: the cache
                # keeps kv_heads, only the compute tensors are repeated
                groups = q.shape[2] // ck.shape[2]
                kk = jnp.repeat(ck, groups, axis=2)
                vv = jnp.repeat(cv, groups, axis=2)
                q = _maybe_constrain(q, None, None, "model", None)
                kk = _maybe_constrain(kk, None, None, "model", None)
                vv = _maybe_constrain(vv, None, None, "model", None)
            out = chunked_attention(q, kk, vv, causal=causal, q_offset=pos,
                                    kv_len=pos + s,
                                    remat_qblock=remat_qblock, amm=amm)
    elif use_pallas and s <= _FLASH_SEQ_CAP and (
            amm is None or amm.attn_lowering is not None):
        groups = q.shape[2] // k.shape[2]
        kk = jnp.repeat(k, groups, axis=2)
        vv = jnp.repeat(v, groups, axis=2)
        qt = q.transpose(0, 2, 1, 3)
        kt = kk.transpose(0, 2, 1, 3)
        vt = vv.transpose(0, 2, 1, 3)
        if amm is None:
            from ..kernels import flash_attention
            out = flash_attention(qt, kt, vt, causal=causal)
        else:
            out = _flash_amm_ste(amm, causal, qt, kt, vt)
        out = out.transpose(0, 2, 1, 3)
    else:
        if use_pallas:
            if s > _FLASH_SEQ_CAP:
                _flash_fallback(
                    "sequence length exceeds the flash cap",
                    shape=x.shape, seq=s, cap=_FLASH_SEQ_CAP,
                    amm="inactive" if amm is None else
                    f"{amm.cfg.mul}/wl={amm.cfg.wl}")
            else:
                _flash_fallback(
                    "amm family has no flash lowering",
                    shape=x.shape, seq=s,
                    amm=f"{amm.cfg.mul}/mode={amm.cfg.mode}")
        if shard_heads and k.shape[2] < q.shape[2]:
            # GQA head sharding: kv_heads (e.g. 8) does not divide the
            # 16-way model axis, which leaves the whole attention replicated
            # per device.  Repeating KV to the full head count lets GSPMD
            # shard the n_heads axis (padding if not divisible) — 16x less
            # attention compute/memory per chip at the price of kv
            # duplication (docs/perf.md §Model-side perf levers).
            groups = q.shape[2] // k.shape[2]
            k = jnp.repeat(k, groups, axis=2)
            v = jnp.repeat(v, groups, axis=2)
            q = _maybe_constrain(q, None, None, "model", None)
            k = _maybe_constrain(k, None, None, "model", None)
            v = _maybe_constrain(v, None, None, "model", None)
        out = chunked_attention(q, k, v, causal=causal,
                                remat_qblock=remat_qblock,
                                causal_skip=causal_skip, p_bf16=p_bf16,
                                amm=amm)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ------------------------------------------------------------ MLA attention
def mla_attention(p, x, cfg: ArchConfig, *, positions, cache=None, pos=None,
                  remat_qblock: bool = False, shard_heads: bool = False,
                  causal_skip: bool = False, p_bf16: bool = False,
                  amm=None):
    """DeepSeek-V3 multi-head latent attention.

    The cache stores the compressed latent (B, S, kv_lora + rope_dim); K/V
    are re-expanded per use (the "naive" formulation — the absorbed-matmul
    decode optimization is a perf item, not a correctness one).  amm: as
    in ``attention`` — the score/value products over the re-expanded K/V
    route through the approximate datapath; the low-rank projections stay
    exact.  Returns (out, new_cache).
    """
    b, s, _ = x.shape
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    # queries through the low-rank path
    q_lat = rmsnorm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # compressed KV latent + decoupled rope key
    latent = x @ p["w_dkv"]                       # (B,S,kv_lora+rope)
    c_kv = rmsnorm(latent[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(latent[..., None, cfg.kv_lora_rank:],
                        positions, cfg.rope_theta)  # (B,S,1,rope)
    lat_cat = jnp.concatenate([c_kv, k_rope[..., 0, :]], axis=-1)

    if cache is not None and s > 1 and jnp.ndim(pos) == 1:
        raise ValueError("multi-token prefill needs a scalar position; "
                         "per-slot position vectors are decode-only")
    if cache is not None and "lat_codes" in cache:
        # int-code latent cache: the compressed latent is quantized at
        # write (frozen codes, first-touch block scales) and dequantized
        # at read — the K/V re-expansion einsums need float latents, so
        # MLA gets the frozen-representation and memory wins of the code
        # cache while its score/value products keep per-call scales over
        # the dequantized values (docs/serving.md)
        if amm is None or amm.attn_lowering is None:
            raise ValueError("int-code KV cache requires an active "
                             "Booth-family bitexact amm attention lowering")
        wl = amm.attn_lowering[0]
        lc, ls = code_cache_update(
            cache["lat_codes"][:, :, None, :], cache["lat_scale"][..., None],
            lat_cat[:, :, None, :], pos, wl=wl)
        new_cache = {"lat_codes": lc[:, :, 0, :], "lat_scale": ls[..., 0]}
        kv_len = pos + s
        lat_all = code_cache_dequant(lc, ls, kv_len=kv_len)[:, :, 0, :]
    elif cache is not None:
        new_lat = _cache_put(cache["latent"],
                             lat_cat.astype(cache["latent"].dtype), pos)
        kv_len = pos + s
        lat_all = new_lat
        new_cache = {"latent": new_lat}
    else:
        lat_all = lat_cat
        kv_len = s
        new_cache = None

    c_all = lat_all[..., :cfg.kv_lora_rank]
    kr_all = lat_all[..., None, cfg.kv_lora_rank:]          # (B,S,1,rope)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_all, p["w_uk"])  # (B,S,H,nope)
    v_all = jnp.einsum("bsr,rhk->bshk", c_all, p["w_uv"])   # (B,S,H,v_hd)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(
            kr_all, k_nope.shape[:3] + (rope_d,))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    if shard_heads and cache is None:
        # MLA has a full per-head K/V after expansion: shard the 128-head
        # axis directly.
        q_full = _maybe_constrain(q_full, None, None, "model", None)
        k_full = _maybe_constrain(k_full, None, None, "model", None)
        v_all = _maybe_constrain(v_all, None, None, "model", None)
    if cache is not None and s == 1:
        out = decode_attention(q_full, k_full, v_all, kv_len=kv_len,
                               amm=amm)
    elif cache is not None:
        out = chunked_attention(q_full, k_full, v_all, causal=True,
                                q_offset=pos, kv_len=kv_len,
                                remat_qblock=remat_qblock, amm=amm)
    else:
        out = chunked_attention(q_full, k_full, v_all, causal=True,
                                remat_qblock=remat_qblock,
                                causal_skip=causal_skip, p_bf16=p_bf16,
                                amm=amm)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache
