"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

One parameter table + one apply function per family concern, composed by
config.  Layers run under ``jax.lax.scan`` over stacked parameters (compile
time stays flat in depth — essential for the 512-device dry-run), with:

  * dense / vlm:  [attn + mlp] x L
  * moe:          first_k_dense dense layers (unstacked python loop), then
                  [attn + moe] scanned; optional MTP head (deepseek)
  * ssm:          [mamba2] x L
  * hybrid:       groups of ``shared_attn_every`` mamba layers, a weight-
                  shared attention+mlp block after each group (zamba2); the
                  shared block's KV caches are stacked per invocation

Modes: "train" (causal, no caches), "prefill" (returns filled caches),
"decode" (single position against caches).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import attention, attn_table, mla_attention, mla_table
from .common import (AmmRuntime, Spec, cross_entropy_loss, init_params,
                     param_logical_axes, rmsnorm)
from .mamba2 import mamba_apply, mamba_table
from .moe import mlp_apply, mlp_table, moe_apply, moe_table

__all__ = ["lm_table", "lm_init", "lm_apply", "lm_amm_planes", "lm_loss",
           "init_cache", "ModelRuntime"]


@dataclasses.dataclass(frozen=True)
class ModelRuntime:
    """Static knobs threaded through apply (jit-static).

    attn_remat / shard_heads are the beyond-paper perf levers recorded in
    docs/perf.md §Model-side perf levers (defaults keep the paper-faithful
    baseline).
    """
    amm: AmmRuntime
    remat: bool = False
    use_pallas_attention: bool = False
    attn_remat: bool = False
    shard_heads: bool = False
    causal_skip: bool = False
    moe_gather_weights: bool = False
    attn_p_bf16: bool = False

    @staticmethod
    def build(cfg: ArchConfig, remat: bool = False,
              use_pallas: bool = False, attn_remat: bool = False,
              shard_heads: bool = False, causal_skip: bool = False,
              moe_gather_weights: bool = False,
              attn_p_bf16: bool = False) -> "ModelRuntime":
        return ModelRuntime(AmmRuntime.build(cfg.amm), remat, use_pallas,
                            attn_remat, shard_heads, causal_skip,
                            moe_gather_weights, attn_p_bf16)

    def build_planes(self, cfg: ArchConfig, params):
        """Per-parameter Booth digit-plane cache for these weights.

        Convenience for serving/eval entry points whose params are fixed:
        ``lm_apply(..., amm_planes=rt.build_planes(cfg, params))`` hoists
        the bitexact datapath's weight decode phase out of every step.
        None when the configured amm mode caches nothing.
        """
        return lm_amm_planes(cfg, self.amm, params)


# ----------------------------------------------------------------- tables
def _attn_block_table(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    t = {"attn_norm": Spec((d,), ("embed",), "ones")}
    t["attn"] = mla_table(cfg) if cfg.use_mla else attn_table(cfg)
    return t


def _dense_layer_table(cfg: ArchConfig, d_ff=None) -> Dict[str, Any]:
    d = cfg.d_model
    t = _attn_block_table(cfg)
    t["mlp_norm"] = Spec((d,), ("embed",), "ones")
    t["mlp"] = mlp_table(d, d_ff or cfg.d_ff)
    return t


def _moe_layer_table(cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    t = _attn_block_table(cfg)
    t["mlp_norm"] = Spec((d,), ("embed",), "ones")
    t["moe"] = moe_table(cfg)
    return t


def _ssm_layer_table(cfg: ArchConfig) -> Dict[str, Any]:
    return {"norm": Spec((cfg.d_model,), ("embed",), "ones"),
            "mamba": mamba_table(cfg)}


def _stack(table: Dict, n: int) -> Dict:
    """Prefix every Spec with a stacked 'layers' axis."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        table, is_leaf=lambda x: isinstance(x, Spec))


def lm_table(cfg: ArchConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    t: Dict[str, Any] = {
        "embed": Spec((v, d), ("vocab", "embed"), "normal", 0.01),
        "final_norm": Spec((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = Spec((d, v), ("embed", "vocab"), "normal", 0.01)

    if cfg.family in ("dense", "vlm", "audio"):
        layer = _dense_layer_table(cfg)
        if cfg.is_encoder_decoder:
            enc_layer = _dense_layer_table(cfg)
            t["encoder"] = {
                "layers": _stack(enc_layer, cfg.n_encoder_layers),
                "norm": Spec((d,), ("embed",), "ones"),
            }
            dec = _dense_layer_table(cfg)
            dec["xattn_norm"] = Spec((d,), ("embed",), "ones")
            dec["xattn"] = attn_table(cfg)
            t["layers"] = _stack(dec, cfg.n_layers)
        else:
            t["layers"] = _stack(layer, cfg.n_layers)
    elif cfg.family == "moe":
        t["dense_prefix"] = [
            _dense_layer_table(cfg) for _ in range(cfg.first_k_dense)]
        t["layers"] = _stack(_moe_layer_table(cfg),
                             cfg.n_layers - cfg.first_k_dense)
        if cfg.mtp_depth:
            mtp = _moe_layer_table(cfg)
            mtp["proj"] = Spec((2 * d, d), (None, "embed"))
            mtp["norm"] = Spec((d,), ("embed",), "ones")
            t["mtp"] = mtp
    elif cfg.family == "ssm":
        t["layers"] = _stack(_ssm_layer_table(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        assert cfg.n_layers % every == 0
        groups, per = cfg.n_layers // every, every
        inner = _stack(_ssm_layer_table(cfg), per)
        t["layers"] = _stack(inner, groups)          # (groups, per, ...)
        t["shared_block"] = _dense_layer_table(cfg)
    else:
        raise ValueError(cfg.family)
    return t


def lm_init(cfg: ArchConfig, key, dtype=jnp.float32):
    return init_params(lm_table(cfg), key, dtype)


def lm_amm_planes(cfg: ArchConfig, amm: AmmRuntime, params):
    """Booth digit-plane cache for every amm-approximated weight.

    The bitexact approximate-matmul datapath quantizes and radix-4-decodes
    its weight operand on every call; weights are constant across decode
    steps and serving requests, so the whole decode phase (dynamic scale +
    digit planes, ``AmmRuntime.precode``) is derived once here and
    threaded through ``lm_apply(amm_planes=...)``.  The tree is sparse —
    it mirrors ``params`` only where ``amm_dense`` is actually applied
    (the gated MLPs: dense/vlm/audio layer stacks, the MoE dense prefix
    and shared experts, the hybrid shared block) — and layer-stacked
    entries keep the layers axis leading so ``jax.lax.scan`` slices them
    exactly like the parameters.  Returns None when nothing is cacheable
    (mode != "bitexact", non-Booth family, SSM-only or encoder-decoder
    configs — the latter fall back to per-call precode inside the layer)
    or when no weight-side matmul routes through amm at all
    (apply_to="attn": ``mlp_apply`` would never read the planes, so
    building them would be dead startup work held for the process
    lifetime).
    """
    if not (amm.cacheable and amm.mlp_active):
        return None
    stacked = jax.vmap(amm.precode)           # (L, K, N) -> per-layer cache

    def mlp(p_mlp, is_stacked):
        f = stacked if is_stacked else amm.precode
        return {k: f(p_mlp[k]) for k in ("w_gate", "w_up", "w_down")}

    if cfg.family in ("dense", "vlm", "audio") and not cfg.is_encoder_decoder:
        return {"layers": {"mlp": mlp(params["layers"]["mlp"], True)}}
    if cfg.family == "moe":
        planes = {"dense_prefix": [{"mlp": mlp(p["mlp"], False)}
                                   for p in params["dense_prefix"]]}
        if cfg.n_shared_experts:
            planes["layers"] = {"moe": {"shared": mlp(
                params["layers"]["moe"]["shared"], True)}}
        return planes
    if cfg.family == "hybrid":
        return {"shared_block": {"mlp": mlp(params["shared_block"]["mlp"],
                                            False)}}
    return None


def lm_logical_axes(cfg: ArchConfig):
    return param_logical_axes(lm_table(cfg))


# ----------------------------------------------------------------- caches
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Decode caches for one full model (layer-stacked)."""
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "vlm", "audio"):
        n = cfg.n_layers
        c = {"k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), dtype),
             "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), dtype)}
        if cfg.is_encoder_decoder:
            c["xk"] = jnp.zeros(
                (n, batch, cfg.encoder_len, cfg.n_kv_heads, hd), dtype)
            c["xv"] = jnp.zeros(
                (n, batch, cfg.encoder_len, cfg.n_kv_heads, hd), dtype)
        return c
    if cfg.family == "moe":
        n = cfg.n_layers
        lat = cfg.kv_lora_rank + cfg.qk_rope_dim
        if cfg.use_mla:
            return {"latent": jnp.zeros((n, batch, max_len, lat), dtype)}
        return {"k": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, hd), dtype)}
    if cfg.family == "ssm":
        return {"ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads,
                                  cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1,
                                   cfg.d_inner + 2 * cfg.ssm_groups
                                   * cfg.ssm_state), dtype)}
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.shared_attn_every
        per = cfg.shared_attn_every
        return {
            "ssm": jnp.zeros((groups, per, batch, cfg.ssm_heads,
                              cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((groups, per, batch, cfg.ssm_conv - 1,
                               cfg.d_inner + 2 * cfg.ssm_groups
                               * cfg.ssm_state), dtype),
            "k": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads, hd),
                           dtype),
            "v": jnp.zeros((groups, batch, max_len, cfg.n_kv_heads, hd),
                           dtype),
        }
    raise ValueError(cfg.family)


# ------------------------------------------------------------------ blocks
def _attn_block(p, h, cfg, rt, *, positions, cache=None, pos=None, kv=None):
    fn = mla_attention if cfg.use_mla else attention
    # apply_to routing: "attn"/"all" (bitexact Booth family) sends the
    # score/value products through the approximate datapath; "mlp" keeps
    # attention exact — bit-identical to the pre-routing code path
    kw = {"remat_qblock": rt.attn_remat, "shard_heads": rt.shard_heads,
          "causal_skip": rt.causal_skip, "p_bf16": rt.attn_p_bf16,
          "amm": rt.amm if rt.amm.attn_active else None}
    if not cfg.use_mla:
        kw.update(use_pallas=rt.use_pallas_attention, kv=kv)
    y, new_cache = fn(p["attn"], rmsnorm(h, p["attn_norm"], cfg.norm_eps),
                      cfg, positions=positions, cache=cache, pos=pos, **kw)
    return h + y.astype(h.dtype), new_cache


def _dense_block(p, h, cfg, rt, key, *, positions, cache=None, pos=None,
                 planes=None):
    h, new_cache = _attn_block(p, h, cfg, rt, positions=positions,
                               cache=cache, pos=pos)
    y = mlp_apply(p["mlp"], rmsnorm(h, p["mlp_norm"], cfg.norm_eps),
                  rt.amm, key, planes=(planes or {}).get("mlp"))
    return h + y.astype(h.dtype), new_cache


def _moe_block(p, h, cfg, rt, key, *, positions, cache=None, pos=None,
               planes=None):
    h, new_cache = _attn_block(p, h, cfg, rt, positions=positions,
                               cache=cache, pos=pos)
    y, aux = moe_apply(p["moe"], rmsnorm(h, p["mlp_norm"], cfg.norm_eps),
                       cfg, amm=rt.amm, key=key,
                       gather_weights=rt.moe_gather_weights,
                       planes=(planes or {}).get("moe"))
    return h + y.astype(h.dtype), new_cache, aux


def _ssm_block(p, h, cfg, rt, *, state=None, conv_state=None):
    y, new_states = mamba_apply(p["mamba"], rmsnorm(h, p["norm"],
                                                    cfg.norm_eps),
                                cfg, state=state, conv_state=conv_state)
    return h + y.astype(h.dtype), new_states


# ------------------------------------------------------------------- apply
def lm_apply(params, cfg: ArchConfig, rt: ModelRuntime, tokens, *,
             mode: str = "train", caches=None, pos=None, rng=None,
             encoder_embeds=None, amm_planes=None):
    """Forward pass.

    tokens: (B, S) int32 (for mode="decode", S == 1).
    encoder_embeds: (B, enc_len, d) precomputed frame embeddings (whisper
    stub frontend).
    amm_planes: optional ``lm_amm_planes`` cache — the bitexact
    approximate-matmul weight decode hoisted out of the step (serving:
    built once at engine construction).  Bit-identical to passing None.
    Returns (logits, aux_losses, new_caches).
    """
    if rng is None:
        rng = jax.random.key(0)
    amm_planes = amm_planes or {}
    h = params["embed"][tokens].astype(jnp.bfloat16)
    b, s = tokens.shape
    # pos: scalar decode front, or a (B,) per-slot vector (continuous
    # batching: every resident request at its own depth)
    off = jnp.asarray(pos if pos is not None else 0)
    if off.ndim == 1:
        off = off[:, None]
    positions = (jnp.arange(s)[None, :] + off) * jnp.ones((b, 1), jnp.int32)
    aux_total = jnp.float32(0.0)
    new_caches: Dict[str, Any] = {}
    decode = mode == "decode"

    def maybe_remat(f):
        return jax.checkpoint(f) if (rt.remat and mode == "train") else f

    # ---------------- encoder (whisper) ----------------
    enc_out = None
    if cfg.is_encoder_decoder:
        assert encoder_embeds is not None
        e = encoder_embeds.astype(h.dtype)
        epos = jnp.arange(e.shape[1])[None, :] * jnp.ones((b, 1), jnp.int32)

        def enc_layer(carry, p_l):
            hh = carry
            hh, _ = _attn_block(p_l, hh, cfg, rt, positions=epos)
            y = mlp_apply(p_l["mlp"],
                          rmsnorm(hh, p_l["mlp_norm"], cfg.norm_eps),
                          rt.amm, rng)
            return hh + y.astype(hh.dtype), None

        enc_out, _ = jax.lax.scan(
            lambda c, p_l: (maybe_remat(enc_layer)(c, p_l)),
            e, params["encoder"]["layers"])
        enc_out = rmsnorm(enc_out, params["encoder"]["norm"], cfg.norm_eps)

    # ---------------- decoder stacks ----------------
    if cfg.family in ("dense", "vlm", "audio") and not cfg.is_encoder_decoder:
        def layer(carry, xs):
            hh, key = carry
            p_l, cache_l, planes_l = xs
            key, sub = jax.random.split(key)
            hh, new_c = _dense_block(
                p_l, hh, cfg, rt, sub, positions=positions,
                cache=cache_l, pos=pos, planes=planes_l)
            return (hh, key), new_c

        # pass the cache dict through whole: the attention layer routes on
        # its keys ({"k","v"} float values vs the int-code leaves)
        cache_xs = caches if caches is not None else None
        (h, _), new_kv = jax.lax.scan(
            maybe_remat(layer), (h, rng),
            (params["layers"], cache_xs, amm_planes.get("layers")))
        if caches is not None:
            new_caches = new_kv

    elif cfg.is_encoder_decoder:
        def dec_layer(carry, xs):
            hh, key = carry
            p_l, cache_l = xs
            key, sub = jax.random.split(key)
            cache_self = ({"k": cache_l["k"], "v": cache_l["v"]}
                          if cache_l is not None else None)
            hh, new_self = _attn_block(p_l, hh, cfg, rt, positions=positions,
                                       cache=cache_self, pos=pos)
            # cross attention: keys/values from encoder output or cache.
            # Same amm routing as _attn_block — the apply_to contract
            # covers every score/value product, cross-attention included
            xamm = rt.amm if rt.amm.attn_active else None
            if cache_l is not None and enc_out is None:
                xkv = (cache_l["xk"], cache_l["xv"])
                xn, _ = attention(
                    p_l["xattn"], rmsnorm(hh, p_l["xattn_norm"], cfg.norm_eps),
                    cfg, positions=positions, kv=xkv, causal=False,
                    amm=xamm)
            else:
                enc_pos = jnp.arange(enc_out.shape[1])[None] * jnp.ones(
                    (b, 1), jnp.int32)
                ek = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["xattn"]["wk"])
                ev = jnp.einsum("bsd,dhk->bshk", enc_out, p_l["xattn"]["wv"])
                ek = ek + (p_l["xattn"]["bk"] if cfg.qkv_bias else 0)
                from .common import apply_rope
                ek = apply_rope(ek, enc_pos, cfg.rope_theta)
                xn, _ = attention(
                    p_l["xattn"], rmsnorm(hh, p_l["xattn_norm"], cfg.norm_eps),
                    cfg, positions=positions, kv=(ek, ev), causal=False,
                    amm=xamm)
            hh = hh + xn.astype(hh.dtype)
            y = mlp_apply(p_l["mlp"], rmsnorm(hh, p_l["mlp_norm"],
                                              cfg.norm_eps), rt.amm, sub)
            new_c = None
            if cache_l is not None:
                new_c = dict(new_self or {"k": cache_l["k"],
                                          "v": cache_l["v"]})
                if enc_out is not None:
                    new_c["xk"], new_c["xv"] = ek.astype(
                        cache_l["xk"].dtype), ev.astype(cache_l["xv"].dtype)
                else:
                    new_c["xk"], new_c["xv"] = cache_l["xk"], cache_l["xv"]
            return (hh + y.astype(hh.dtype), key), new_c

        (h, _), new_kv = jax.lax.scan(
            maybe_remat(dec_layer), (h, rng), (params["layers"], caches))
        if caches is not None:
            new_caches = new_kv

    elif cfg.family == "moe":
        # unstacked dense prefix
        prefix_planes = amm_planes.get("dense_prefix") or []
        prefix_new = []
        for i, p_l in enumerate(params["dense_prefix"]):
            cache_l = (jax.tree.map(lambda c: c[i], caches)
                       if caches is not None else None)
            rng, sub = jax.random.split(rng)
            h, new_c = _dense_block(p_l, h, cfg, rt, sub,
                                    positions=positions,
                                    cache=cache_l, pos=pos,
                                    planes=(prefix_planes[i]
                                            if i < len(prefix_planes)
                                            else None))
            prefix_new.append(new_c)

        def layer(carry, xs):
            hh, key, aux = carry
            p_l, cache_l, planes_l = xs
            key, sub = jax.random.split(key)
            hh, new_c, aux_l = _moe_block(p_l, hh, cfg, rt, sub,
                                          positions=positions,
                                          cache=cache_l, pos=pos,
                                          planes=planes_l)
            return (hh, key, aux + aux_l), new_c

        k_pref = cfg.first_k_dense
        cache_xs = (jax.tree.map(lambda c: c[k_pref:], caches)
                    if caches is not None else None)
        (h, _, aux_total), new_kv = jax.lax.scan(
            maybe_remat(layer), (h, rng, aux_total),
            (params["layers"], cache_xs, amm_planes.get("layers")))
        if caches is not None:
            # re-assemble the full layer-stacked cache (prefix + scanned)
            stacked_prefix = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *prefix_new) \
                if prefix_new else None
            if stacked_prefix is not None:
                new_caches = jax.tree.map(
                    lambda a, b2: jnp.concatenate([a, b2], axis=0),
                    stacked_prefix, new_kv)
            else:
                new_caches = new_kv

    elif cfg.family == "ssm":
        def layer(carry, xs):
            hh = carry
            p_l, st = xs
            state = st["ssm"] if st is not None else None
            conv = st["conv"] if st is not None else None
            hh, (ns, ncv) = _ssm_block(p_l, hh, cfg, rt,
                                       state=state, conv_state=conv)
            out = ({"ssm": ns, "conv": ncv} if ns is not None else None)
            return hh, out

        st_xs = ({"ssm": caches["ssm"], "conv": caches["conv"]}
                 if caches is not None else None)
        h, new_st = jax.lax.scan(maybe_remat(layer), h,
                                 (params["layers"], st_xs))
        if caches is not None:
            new_caches = new_st

    elif cfg.family == "hybrid":
        shared = params["shared_block"]

        def group(carry, xs):
            hh, key = carry
            p_g, st_g = xs

            def inner(c, xs2):
                h2 = c
                p_l, st = xs2
                state = st["ssm"] if st is not None else None
                conv = st["conv"] if st is not None else None
                h2, (ns, ncv) = _ssm_block(p_l, h2, cfg, rt,
                                           state=state, conv_state=conv)
                return h2, ({"ssm": ns, "conv": ncv}
                            if ns is not None else None)

            ssm_xs = ({"ssm": st_g["ssm"], "conv": st_g["conv"]}
                      if st_g is not None else None)
            hh, new_inner = jax.lax.scan(inner, hh, (p_g, ssm_xs))
            key, sub = jax.random.split(key)
            cache_g = ({"k": st_g["k"], "v": st_g["v"]}
                       if st_g is not None else None)
            hh, new_kv_g = _dense_block(shared, hh, cfg, rt, sub,
                                        positions=positions,
                                        cache=cache_g, pos=pos,
                                        planes=amm_planes.get(
                                            "shared_block"))
            out = None
            if st_g is not None:
                out = {"ssm": new_inner["ssm"], "conv": new_inner["conv"],
                       "k": new_kv_g["k"], "v": new_kv_g["v"]}
            return (hh, key), out

        (h, _), new_g = jax.lax.scan(maybe_remat(group), (h, rng),
                                     (params["layers"], caches))
        if caches is not None:
            new_caches = new_g
    else:
        raise ValueError(cfg.family)

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
    return logits, {"moe_aux": aux_total}, new_caches


def lm_loss(params, cfg: ArchConfig, rt: ModelRuntime, tokens, labels, *,
            rng=None, encoder_embeds=None, moe_aux_weight: float = 1e-2,
            mtp_weight: float = 0.1, amm_planes=None):
    """Training loss: next-token CE + MoE aux (+ MTP if configured).

    amm_planes is accepted for API symmetry with ``lm_apply`` (eval loss
    over fixed weights); training steps pass None — the weights change
    every update, so there is nothing to cache across calls.
    """
    logits, aux, _ = lm_apply(params, cfg, rt, tokens, mode="train", rng=rng,
                              encoder_embeds=encoder_embeds,
                              amm_planes=amm_planes)
    loss = cross_entropy_loss(logits, labels)
    total = loss + moe_aux_weight * aux["moe_aux"]
    metrics = {"ce": loss, "moe_aux": aux["moe_aux"]}
    if cfg.mtp_depth and "mtp" in params:
        # MTP: predict token t+2 from (h_t, emb(label_t)) through one extra
        # block (deepseek-v3 §MTP, depth 1).
        p_m = params["mtp"]
        h_in = params["embed"][tokens].astype(jnp.bfloat16)
        emb_next = params["embed"][labels].astype(jnp.bfloat16)
        h_m = jnp.concatenate([rmsnorm(h_in, p_m["norm"], cfg.norm_eps),
                               emb_next], axis=-1) @ p_m["proj"]
        b, s = tokens.shape
        positions = jnp.arange(s)[None, :] * jnp.ones((b, 1), jnp.int32)
        mtp_rng = rng if rng is not None else jax.random.key(1)
        h_m, _, _aux = _moe_block(p_m, h_m, cfg, rt, mtp_rng,
                                  positions=positions)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits_m = (rmsnorm(h_m, params["final_norm"], cfg.norm_eps)
                    @ head.astype(h_m.dtype)).astype(jnp.float32)
        # labels shifted once more (t+2): reuse labels rolled by 1
        labels2 = jnp.roll(labels, -1, axis=-1)
        mtp_loss = cross_entropy_loss(logits_m[:, :-1], labels2[:, :-1])
        total = total + mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    return total, metrics
