"""Mixture-of-Experts block with sort-based capacity dispatch.

Dispatch is the standard dropping formulation: tokens are routed to their
top-k experts, each expert processes at most ``capacity`` tokens
(capacity_factor * k * T / E), overflow tokens lose that expert's
contribution.  Implemented with sort/cumsum/scatter only — no (T, E, C)
one-hot tensors — so it scales to 256 experts x 1M tokens and shards with
experts on the "model" mesh axis (expert parallelism; XLA inserts the
all-to-alls at the dispatch/combine boundaries).

The router follows DeepSeek-V3: sigmoid affinities, top-k, normalized
weights, plus an auxiliary load-balance loss (Switch-style) returned to the
caller.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import Spec

__all__ = ["moe_table", "moe_apply", "mlp_table", "mlp_apply"]


def mlp_table(d_model: int, d_ff: int, prefix_axes=("embed", "mlp")) -> Dict:
    a_in, a_out = prefix_axes
    return {
        "w_gate": Spec((d_model, d_ff), (a_in, a_out)),
        "w_up": Spec((d_model, d_ff), (a_in, a_out)),
        "w_down": Spec((d_ff, d_model), (a_out, a_in)),
    }


def mlp_apply(p, x, amm=None, key=None, planes=None):
    """Gated MLP; ``planes`` is the optional per-weight digit-plane cache
    (``{"w_gate": .., "w_up": .., "w_down": ..}`` of ``AmmRuntime.precode``
    entries) for the bitexact approximate-matmul datapath.  Routing
    follows ``AmmRuntime.mlp_active``: apply_to="attn" leaves the MLPs
    exact so the attention contribution is measurable in isolation."""
    from .common import amm_dense
    if amm is not None and amm.mlp_active:
        pl_ = planes or {}
        g = amm_dense(x, p["w_gate"], amm, key, planes=pl_.get("w_gate"))
        u = amm_dense(x, p["w_up"], amm, key, planes=pl_.get("w_up"))
        h = jax.nn.silu(g) * u
        return amm_dense(h, p["w_down"], amm, key, planes=pl_.get("w_down"))
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def moe_table(cfg: ArchConfig) -> Dict[str, Spec]:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    t = {
        "router": Spec((d, e), ("embed", "experts"), "normal", 0.006),
        "w_gate": Spec((e, d, ff), ("experts", "embed", "expert_mlp")),
        "w_up": Spec((e, d, ff), ("experts", "embed", "expert_mlp")),
        "w_down": Spec((e, ff, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        sff = ff * cfg.n_shared_experts
        t["shared"] = mlp_table(d, sff)
    return t


def _dispatch(expert_ids, top_k: int, n_tokens: int, n_experts: int,
              capacity: int):
    """Build gather indices from flat (T*k,) routing decisions.

    Returns (slot_token, token_slot):
      slot_token: (E*C,) *token* index feeding each expert slot (T = pad)
      token_slot: (T*k,) slot index each routing decision landed in (E*C =
                  dropped/pad)
    """
    tk = expert_ids.shape[0]
    # decisions sorted by expert, stable -> token order within expert
    order = jnp.argsort(expert_ids, stable=True)               # (T*k,)
    sorted_e = expert_ids[order]
    # rank within expert = sorted index - start offset of that expert
    counts = jnp.bincount(expert_ids, length=n_experts)
    starts = jnp.cumsum(counts) - counts                       # (E,)
    rank = jnp.arange(tk) - starts[sorted_e]                   # (T*k,)
    keep = rank < capacity
    nc = n_experts * capacity
    slot = sorted_e * capacity + jnp.minimum(rank, capacity - 1)
    oob = jnp.where(keep, slot, nc)            # out-of-bounds -> dropped
    # scatter token ids into slots (mode="drop" discards overflow)
    slot_token = jnp.full((nc,), n_tokens, jnp.int32)
    slot_token = slot_token.at[oob].set(
        (order // top_k).astype(jnp.int32), mode="drop")
    token_slot = jnp.full((tk,), nc, jnp.int32)
    token_slot = token_slot.at[order.astype(jnp.int32)].set(
        oob.astype(jnp.int32))
    return slot_token, token_slot


def moe_apply(p, x, cfg: ArchConfig, *, capacity_factor: float = 1.25,
              amm=None, key=None, gather_weights: bool = False,
              planes=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).  ``planes``: optional digit-plane
    cache for the shared-expert MLP (``{"shared": {...}}``).

    Decode (s == 1) runs dropless (capacity = T): a decode step must not
    lose expert contributions to capacity, and the buffers are tiny there.

    gather_weights: constrain expert weights to P("model", None, None)
    before the expert einsums.  Under FSDP rules the weights' d axis is
    sharded over "data", and GSPMD resolves the contraction by ALL-REDUCING
    the (E, C, d_ff) partial products — tens of GB of f32 per layer (the
    dominant collective term of the MoE baselines, docs/perf.md
    §Model-side perf levers).  Gathering the weights instead moves ~30x
    fewer bytes.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    if s == 1:
        capacity_factor = e / k        # capacity == t: no drops
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.sigmoid(logits)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    sprobs = jax.nn.softmax(logits, axis=-1)
    frac_routed = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(frac_routed * jnp.mean(sprobs, axis=0))

    capacity = max(int(capacity_factor * k * t / e), 1)
    flat_e = gate_idx.reshape(-1)                              # (T*k,)
    slot_token, token_slot = _dispatch(flat_e, k, t, e, capacity)

    # gather tokens into (E, C, d), run experts batched, gather back
    xg = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xg[slot_token].reshape(e, capacity, d)
    w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    if gather_weights:
        from .attention import _maybe_constrain
        if e % 16 == 0:              # EP: experts carry the model axis
            ax_up, ax_down = ("model", None, None), ("model", None, None)
        else:                        # TP-experts (grok: 8 experts, 16-way)
            ax_up, ax_down = (None, None, "model"), (None, "model", None)
        w_gate = _maybe_constrain(w_gate, *ax_up)
        w_up = _maybe_constrain(w_up, *ax_up)
        w_down = _maybe_constrain(w_down, *ax_down)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", xe, w_up)
    h = h.astype(xe.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)                 # (E,C,d)
    yflat = jnp.concatenate(
        [ye.reshape(e * capacity, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    per_decision = yflat[token_slot].reshape(t, k, d)          # (T,k,d)
    y = jnp.einsum("tkd,tk->td", per_decision,
                   gate_vals.astype(per_decision.dtype))

    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], xf, amm, key,
                          planes=(planes or {}).get("shared"))
    return y.reshape(b, s, d), aux
