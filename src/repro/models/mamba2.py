"""Mamba2 SSD (state-space duality) block, chunked matmul formulation.

Follows Dao & Gu (arXiv:2405.21060): within a chunk of length Q the output
is an attention-like masked matmul (MXU-friendly); across chunks a small
(H, P, N) state is carried by a linear recurrence (lax.scan).  A sequential
per-step reference (`ssd_reference`) backs the tests, and `ssd_decode_step`
is the O(1) per-token serving path — the reason the long_500k shape runs for
SSM/hybrid archs only.

Shapes: x (B, L, H, P) values; dt (B, L, H) positive step sizes;
A (H,) negative decay rates; B_, C_ (B, L, G, N) in/out projections
(G groups broadcast over H); D (H,) skip.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import Spec

__all__ = ["mamba_table", "mamba_apply", "mamba_decode_step",
           "ssd_chunked", "ssd_reference", "ssd_decode_step"]


# ------------------------------------------------------------------ params
def mamba_table(cfg: ArchConfig) -> Dict[str, Spec]:
    d, di = cfg.d_model, cfg.d_inner
    h, n, g = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    conv_dim = di + 2 * g * n
    return {
        "in_proj": Spec((d, 2 * di + 2 * g * n + h), ("embed", "ssm_inner")),
        "conv_w": Spec((cfg.ssm_conv, conv_dim), ("conv", "ssm_inner"),
                       "normal", 0.2),
        "conv_b": Spec((conv_dim,), ("ssm_inner",), "zeros"),
        "a_log": Spec((h,), ("ssm_heads",), "ones"),
        "dt_bias": Spec((h,), ("ssm_heads",), "zeros"),
        "d_skip": Spec((h,), ("ssm_heads",), "ones"),
        "norm_w": Spec((di,), ("ssm_inner",), "ones"),
        "out_proj": Spec((di, d), ("ssm_inner", "embed")),
    }


# ------------------------------------------------------------------- SSD
def ssd_chunked(x, dt, A, B_, C_, D, *, chunk: int):
    """Chunked SSD scan.  Returns (y, final_state).

    x: (B,L,H,P); dt: (B,L,H); A: (H,); B_/C_: (B,L,G,N); D: (H,)
    state: (B,H,P,N)
    """
    b, l, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    q = min(chunk, l)
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"
    nc = l // q
    rep = h // g

    xr = x.reshape(b, nc, q, h, p)
    dtr = dt.reshape(b, nc, q, h)
    br = jnp.repeat(B_.reshape(b, nc, q, g, n), rep, axis=3)   # (b,nc,q,h,n)
    cr = jnp.repeat(C_.reshape(b, nc, q, g, n), rep, axis=3)

    dA = dtr * A                                               # (b,nc,q,h) <0
    cum = jnp.cumsum(dA, axis=2)                               # within chunk

    # ---- intra-chunk (attention-like) term
    # L[i,j] = exp(cum_i - cum_j) for i >= j (decay from j+1..i)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # (b,nc,q,q,h)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    lmat = jnp.where(mask, jnp.exp(li), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", cr, br)          # (b,nc,q,q,h)
    w = scores * lmat * dtr[:, :, None, :, :]                  # dt_j weight
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xr)

    # ---- chunk states: S_c = sum_j exp(cumQ - cum_j) dt_j B_j (x) x_j
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)               # (b,nc,q,h)
    sb = br * (decay_end * dtr)[..., None]                     # (b,nc,q,h,n)
    s_c = jnp.einsum("bcjhn,bcjhp->bchpn", sb, xr)             # (b,nc,h,p,n)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # (b,nc,h)

    # ---- inter-chunk recurrence
    def step(hstate, inp):
        s_chunk, dec = inp                                     # (b,h,p,n),(b,h)
        new = hstate * dec[:, :, None, None] + s_chunk
        return new, hstate                                     # emit prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, h_prev = jax.lax.scan(
        step, init,
        (s_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                   # (b,nc,h,p,n)

    # ---- inter-chunk output: C_i . (h_prev * decay_to_i)
    dec_in = jnp.exp(cum)                                      # (b,nc,q,h)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp",
                         cr * dec_in[..., None], h_prev)

    y = (y_intra + y_inter).reshape(b, l, h, p)
    y = y + x * D[None, None, :, None]
    return y, final


def ssd_reference(x, dt, A, B_, C_, D):
    """Sequential per-step oracle: h_t = h_{t-1} exp(dt_t A) + dt_t B_t x_t."""
    b, l, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    rep = h // g
    br = jnp.repeat(B_, rep, axis=2)
    cr = jnp.repeat(C_, rep, axis=2)

    def step(hstate, inp):
        xt, dtt, bt, ct = inp                 # (b,h,p),(b,h),(b,h,n),(b,h,n)
        dec = jnp.exp(dtt * A)                # (b,h)
        hnew = (hstate * dec[..., None, None]
                + jnp.einsum("bhn,bhp->bhpn", bt * dtt[..., None], xt))
        y = jnp.einsum("bhn,bhpn->bhp", ct, hnew)
        return hnew, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, init,
                         (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
                          br.transpose(1, 0, 2, 3), cr.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3)
    return y + x * D[None, None, :, None]


def ssd_decode_step(state, xt, dtt, A, bt, ct, D):
    """One-token state update.  state (B,H,P,N) -> (y_t, new_state)."""
    dec = jnp.exp(dtt * A)
    new = (state * dec[..., None, None]
           + jnp.einsum("bhn,bhp->bhpn", bt * dtt[..., None], xt))
    y = jnp.einsum("bhn,bhpn->bhp", ct, new) + xt * D[None, :, None]
    return y, new


# ------------------------------------------------------------ full block
def _causal_conv(xbc, w, b_, conv_state=None):
    """Depthwise causal conv over (B, L, C) with kernel (K, C).

    conv_state: (B, K-1, C) history for decode; returns (y, new_state).
    """
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([conv_state, xbc], axis=1)
    new_state = pad[:, -(k - 1):] if k > 1 else None
    y = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(y + b_), new_state


def _split_proj(proj, cfg: ArchConfig):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * g * n]
    dt_raw = proj[..., -h:]
    return z, xbc, dt_raw


def mamba_apply(p, x, cfg: ArchConfig, *, state=None, conv_state=None):
    """Full Mamba2 block.  x: (B, S, d_model).

    Training/prefill: state/conv_state None -> chunked scan.
    Decode: pass (state, conv_state), S == 1.
    Returns (y, (new_state, new_conv_state)).
    """
    b, s, d = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_headdim
    if state is not None and s > 1:
        # multi-token prefill: run the chunked scan from zero state (the
        # cache is being filled from position 0)
        state, conv_state = None, None
        prefill = True
    else:
        prefill = False
    proj = x @ p["in_proj"]
    z, xbc, dt_raw = _split_proj(proj, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :di].reshape(b, s, h, pdim)
    b_ = xbc[..., di:di + g * n].reshape(b, s, g, n)
    c_ = xbc[..., di + g * n:].reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    if state is None:
        y, new_state = ssd_chunked(xs.astype(jnp.float32),
                                   dt.astype(jnp.float32), A,
                                   b_.astype(jnp.float32),
                                   c_.astype(jnp.float32),
                                   p["d_skip"].astype(jnp.float32),
                                   chunk=min(cfg.ssm_chunk, s))
    else:
        rep = h // g
        bt = jnp.repeat(b_[:, 0], rep, axis=1)
        ct = jnp.repeat(c_[:, 0], rep, axis=1)
        y1, new_state = ssd_decode_step(
            state, xs[:, 0].astype(jnp.float32), dt[:, 0].astype(jnp.float32),
            A, bt.astype(jnp.float32), ct.astype(jnp.float32),
            p["d_skip"].astype(jnp.float32))
        y = y1[:, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    # gated RMSNorm (mamba2 style)
    from .common import rmsnorm
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], (new_state, new_conv)


def mamba_decode_step(p, x, cfg, state, conv_state):
    return mamba_apply(p, x, cfg, state=state, conv_state=conv_state)
