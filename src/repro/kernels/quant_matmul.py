"""Pallas TPU kernel: fixed-point quantized matmul with fused approximate-
multiplier noise injection (the scalable "silicon simulation" fast path).

Implements the paper's §II.B white-noise error model generatively:

    out = (x_q @ w_q) * s_x * s_w  +  eps,
    eps ~ Normal(K * mu, K * sigma^2) * s_x * s_w   per output element

where (mu, sigma) are the characterized per-product error moments of the
chosen approximate multiplier (core.noise.NoiseModel) in the integer domain,
and K is the contraction length.  The matmul itself runs on the MXU in
bf16->f32; the noise is generated *inside the kernel* from a counter-based
hash (squares64-style) keyed on (seed, tile coordinates, lane), so the kernel
stays a single fused pass over VMEM tiles: quantize -> MXU -> noise -> scale.

The quantization scales and the noise seed enter as tiny *operand* blocks
(a (1, 2) f32 scale pair and a (1, 1) int32 seed, broadcast to every tile),
not as trace-time constants: ``amm_dense`` computes its scales dynamically
from the activations (``jnp.max(|x|)``) inside the jitted train/serve step,
so the kernel must accept traced scalars — and a traced seed keeps one
compiled kernel across noise draws instead of one per seed.  (mu, sigma)
stay static: they come from the characterization cache as python floats.

This is the TPU-native statement of the paper's idea at model scale: the
quality impact of the proposed multiplier on a workload can be evaluated at
full training/serving throughput, because the error model — not the broken
datapath — is what executes.  ``models.common.amm_dense`` reaches it via
``AmmConfig.use_pallas`` for mode="noise".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["quant_matmul_kernel", "quant_matmul"]


def _hash_normal(shape, seed, salt):
    """Two rounds of a squares-style counter hash -> approx N(0,1).

    Box-Muller over two uint32 uniforms derived from (seed, salt, position).
    Statistical quality is ample for noise injection (validated in
    tests/test_kernels.py against moment targets).
    """
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 2)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, len(shape) - 1)
    ctr = r * jnp.uint32(0x9E3779B9) + c * jnp.uint32(0x85EBCA6B)
    ctr = ctr + seed.astype(jnp.uint32) * jnp.uint32(0xC2B2AE35)
    ctr = ctr + salt.astype(jnp.uint32) * jnp.uint32(0x27D4EB2F)

    def squares(x, key):
        x = x * key
        x = (x >> 16) | (x << 16)
        x = x * x + key
        x = (x >> 16) | (x << 16)
        x = x * x + key
        return x

    u1 = squares(ctr, jnp.uint32(0xB5AD4ECE)).astype(jnp.float32) / 4294967296.0
    u2 = squares(ctr ^ jnp.uint32(0xDEADBEEF),
                 jnp.uint32(0x548C9DEC)).astype(jnp.float32) / 4294967296.0
    u1 = jnp.clip(u1, 1e-7, 1.0)              # uniforms in [0, 1)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)


def quant_matmul_kernel(x_ref, w_ref, s_ref, seed_ref, o_ref, *, mu: float,
                        sigma: float, k_total: int, n_k: int, wl: int):
    """One (bm, bn) tile; K streamed on grid axis 2, noise added on last step.

    s_ref: (1, 2) f32 [s_x, s_w]; seed_ref: (1, 1) int32 — the same block
    broadcast to every grid point.
    """
    k_idx = pl.program_id(2)
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    lim = float(2 ** (wl - 1))
    sx = s_ref[0, 0]
    sw = s_ref[0, 1]
    xq = jnp.clip(jnp.round(x_ref[...] / sx), -lim, lim - 1)
    wq = jnp.clip(jnp.round(w_ref[...] / sw), -lim, lim - 1)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k_idx == n_k - 1)
    def _finalize():
        salt = i * jnp.int32(7919) + j
        z = _hash_normal(o_ref.shape, seed_ref[0, 0], salt)
        eps = mu * k_total + sigma * jnp.sqrt(float(k_total)) * z
        o_ref[...] = (o_ref[...] + eps) * (sx * sw)


@functools.partial(jax.jit, static_argnames=("mu", "sigma", "wl", "bm",
                                             "bk", "bn", "interpret"))
def quant_matmul(x, w, s_x, s_w, mu, sigma, *, wl: int = 16,
                 bm: int = 128, bk: int = 512, bn: int = 128,
                 seed=0, interpret: bool = False):
    """Fused quantize->matmul->noise->dequantize.

    x: (M, K) float, w: (K, N) float; s_x, s_w: quantization scales (real
    value = code * s) — python floats or traced f32 scalars; seed: python
    int or traced int32 scalar; mu, sigma: per-product integer-domain
    error moments of the multiplier spec being simulated (static floats
    from the characterization cache).
    """
    mm, kk = x.shape
    _, nn = w.shape
    bm = min(bm, mm)
    bn = min(bn, nn)
    bk = min(bk, kk)
    scales = jnp.stack([jnp.asarray(s_x, jnp.float32),
                        jnp.asarray(s_w, jnp.float32)]).reshape(1, 2)
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    grid = (pl.cdiv(mm, bm), pl.cdiv(nn, bn), pl.cdiv(kk, bk))
    kernel = functools.partial(
        quant_matmul_kernel,
        mu=float(mu), sigma=float(sigma), k_total=kk, n_k=grid[2], wl=wl)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.float32),
        interpret=interpret,
    )(x, w, scales, seed_arr)
