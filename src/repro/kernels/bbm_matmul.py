"""Pallas TPU kernel: bit-exact Broken-Booth approximate matmul,
precoded-digit datapath.

Computes ``out[m, n] = sum_k shift(bbm(x[m, k], w[k, n]))`` where ``bbm`` is
the closed-form Broken-Booth product (Type0/Type1) and ``shift`` an optional
arithmetic right shift applied per product (the fixed-point MAC rescale).

TPU adaptation notes (this is the paper's multiplier *as a TPU kernel*):
  * The MXU performs exact multiplies only — but that does NOT keep a broken
    multiplier off it: clearing the low ``m`` bits of a two's-complement row
    is subtraction of its low bits, so every BBM product is the *exact*
    product minus a correction built from the low ``vbl`` bits of ``x``
    (``booth_rows.booth_correction``), and folding the correction's own
    linear term back into the contraction gives
    ``bbm(x, w) == 2^vbl * (x*wq + truncated-row terms)``.  ``form="dot"``
    computes exactly that: the dominant ``x @ wq`` contraction rides the
    hardware's native matmul units (MXU on TPU, XLA's matmul lowering on
    CPU) and only the ``ceil(vbl/2)`` truncated digit planes are walked
    elementwise.  ``form="rows"`` keeps the pure-VPU row emulation — still
    the bit-exact reference datapath for validating the silicon and
    calibrating the statistical noise model that the quantized fast path
    (quant_matmul) uses.  ``form=None`` auto-picks the dot form; its
    scaled accumulation stays inside the rows-form int32 envelope for
    every vbl (``booth_rows.dotform_scaled_bound`` has the re-derived
    analysis).
  * ``w`` is the Booth *multiplier* operand and is constant across the whole
    grid (every (i, j) tile re-reads the same weight blocks), so its radix-4
    digits are decoded exactly once per call by ``booth_rows.booth_precode``
    and streamed in as ``(wl//2, K, N)`` planes, BlockSpec-tiled like ``w``
    itself.  The in-kernel row loop is then multiply-free (select/negate/
    shift per row).  ``bbm_matmul`` keeps the raw-code signature and
    precodes internally; ``bbm_matmul_precoded`` accepts decoded planes for
    callers whose weights are long-lived.
  * The Booth row loop (wl/2 iterations) is unrolled at trace time; each row
    materializes one (bm, bk, bn) int32 tile in VMEM.  With the default
    64x64x64 blocking that is 1 MiB live — comfortably inside the ~16 MiB
    VMEM budget together with the x/w/out tiles.
  * Accumulation is int32.  Callers must respect the documented overflow
    envelope: K * 2^(2*wl - 1 - shift) < 2^31 (asserted in ops.py).

Block shapes are (bm, bk) x (bk, bn) -> (bm, bn) with a 3-D grid over
(M/bm, N/bn, K/bk); the K axis accumulates in place (output revisited).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.booth import num_pp_rows
from .booth_rows import (bbm_rows_product_precoded, booth_high_value,
                         booth_precode, resolve_form, scaled_trunc_rows,
                         split_signed)

__all__ = ["bbm_matmul_kernel", "bbm_matmul", "bbm_matmul_precoded"]

# auto-form only: above this many int32 elements the dot form's (M, K, N)
# truncated-row correction temporary stops being a fair trade against the
# tiled rows kernel, so form=None falls back to streaming.  An explicit
# form="dot" is honored regardless — the caller owns the memory then.
_DOT_CORR_BUDGET = 1 << 26


def _matmul_dotform(x, wmag, wneg, *, wl: int, vbl: int, kind: int,
                    shift: int):
    """Dot-form matmul: one dense contraction + scaled truncated rows.

    Bit-identical to the rows kernel.  Every BBM product is ``2^vbl * M``
    with ``M = x*wq + sum_{r<R} ((d_r*x - neg_r*kind) >> m_r)`` (see
    ``booth_rows.dotform_scaled_bound``): the dominant term is a plain
    ``x @ wq`` integer matmul — the MXU on TPU, XLA's matmul lowering on
    CPU — and only the ``R = ceil(vbl/2)`` truncated digit planes walk an
    (M, K, N) elementwise correction (the im2col trade).  Accumulating at
    the ``2^-max(vbl, shift)`` scale keeps every partial sum inside the
    rows-form int32 envelope.
    """
    _, x_s = split_signed(x, wl)
    wq = booth_high_value(wmag, wneg, wl=wl, vbl=vbl)        # (K, N)
    u = max(shift - vbl, 0)       # per-product residual rescale (rare)
    q = scaled_trunc_rows(x_s[:, :, None], wmag[:, None, :, :],
                          wneg[:, None, :, :], wl=wl, vbl=vbl,
                          kind=kind)                         # (M, K, N)
    if u == 0:
        acc = jax.lax.dot(x_s, wq, preferred_element_type=jnp.int32)
        if q is not None:
            acc = acc + jnp.sum(q, axis=1, dtype=jnp.int32)
    else:
        # shift > vbl: the residual floor applies per product, before
        # the K reduction
        m_prod = x_s[:, :, None] * wq[None]
        if q is not None:
            m_prod = m_prod + q
        acc = jnp.sum(m_prod >> u, axis=1, dtype=jnp.int32)
    if vbl > shift:
        acc = acc << (vbl - shift)
    return acc


def bbm_matmul_kernel(x_ref, wm_ref, ws_ref, o_ref, *, wl: int, vbl: int,
                      kind: int, shift: int, n_k: int):
    """One (bm, bn) output tile; grid axis 2 streams K blocks."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                      # (bm, bk) int32, wl-bit codes
    _, x_s = split_signed(x, wl)
    a = x_s[:, :, None]                                      # (bm, bk, 1)
    # (wl//2, bk, bn) digit planes; row r broadcasts (bk, bn) against a
    prod = bbm_rows_product_precoded(a, wm_ref[...], ws_ref[...],
                                     wl=wl, vbl=vbl, kind=kind)
    # per-product rescale then reduce over the k axis of the tile
    if shift:
        prod = prod >> shift
    o_ref[...] += jnp.sum(prod, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "shift",
                                             "bm", "bk", "bn", "interpret",
                                             "form"))
def bbm_matmul_precoded(x, wmag, wneg, *, wl: int, vbl: int, kind: int = 0,
                        shift: int = 0, bm: int = 64, bk: int = 64,
                        bn: int = 64, interpret: bool = False,
                        form: str | None = None):
    """Tiled approximate matmul on precoded weight-digit planes.

    x: (M, K) int32 codes; wmag, wneg: (wl//2, K, N) planes from
    ``booth_precode`` of the (K, N) weight code matrix.
    form: "rows" (VPU row emulation), "dot" (dense contraction + scaled
    truncated rows, on the matmul units) or None (auto: the dot form).
    Bit-identical; ``bm``/``bk``/``bn``/``interpret`` only shape the rows
    form.
    """
    mm, kk = x.shape
    n_rows, kk2, nn = wmag.shape
    if wmag.shape != wneg.shape:
        raise ValueError(f"mag/neg plane shapes differ: "
                         f"{wmag.shape} vs {wneg.shape}")
    if n_rows != num_pp_rows(wl) or kk != kk2:
        raise ValueError(f"digit planes {wmag.shape} do not match "
                         f"wl={wl}, K={kk}")
    if form is None and (vbl or shift) and mm * kk * nn > _DOT_CORR_BUDGET:
        # both the truncated-row correction (vbl > 0) and the per-product
        # residual floor (shift > vbl, incl. vbl = 0) materialize an
        # (M, K, N) temporary; only the pure dot (vbl = shift = 0) is free
        form = "rows"
    if resolve_form(form) == "dot":
        return _matmul_dotform(x, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                               shift=shift)
    grid = (pl.cdiv(mm, bm), pl.cdiv(nn, bn), pl.cdiv(kk, bk))
    kernel = functools.partial(bbm_matmul_kernel, wl=wl, vbl=vbl, kind=kind,
                               shift=shift, n_k=grid[2])
    plane_spec = pl.BlockSpec((n_rows, bk, bn), lambda i, j, k: (0, k, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            plane_spec,
            plane_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.int32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wmag, wneg)


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "shift",
                                             "bm", "bk", "bn", "interpret",
                                             "form"))
def bbm_matmul(x, w, *, wl: int, vbl: int, kind: int = 0, shift: int = 0,
               bm: int = 64, bk: int = 64, bn: int = 64,
               interpret: bool = False, form: str | None = None):
    """Tiled bit-exact approximate matmul.  x: (M, K) w: (K, N), int32 codes.

    Thin raw-code wrapper: precodes ``w`` once (hoisting the recode out of
    the grid, which re-reads every weight block M/bm times) and dispatches
    to ``bbm_matmul_precoded``.
    """
    wmag, wneg = booth_precode(w, wl)
    return bbm_matmul_precoded(x, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                               shift=shift, bm=bm, bk=bk, bn=bn,
                               interpret=interpret, form=form)
