"""Pallas TPU kernel: bit-exact Broken-Booth approximate matmul,
precoded-digit datapath.

Computes ``out[m, n] = sum_k shift(bbm(x[m, k], w[k, n]))`` where ``bbm`` is
the closed-form Broken-Booth product (Type0/Type1) and ``shift`` an optional
arithmetic right shift applied per product (the fixed-point MAC rescale).

TPU adaptation notes (this is the paper's multiplier *as a TPU kernel*):
  * The MXU performs exact multiplies only — but that does NOT keep a broken
    multiplier off it: clearing the low ``m`` bits of a two's-complement row
    is subtraction of its low bits, so every BBM product is the *exact*
    product minus a correction built from the low ``vbl`` bits of ``x``
    (``booth_rows.booth_correction``), and folding the correction's own
    linear term back into the contraction gives
    ``bbm(x, w) == 2^vbl * (x*wq + truncated-row terms)``.  ``form="dot"``
    computes exactly that: the dominant ``x @ wq`` contraction rides the
    hardware's native matmul units (MXU on TPU, XLA's matmul lowering on
    CPU), and each of the ``ceil(vbl/2)`` truncated rows folds into a few
    more narrow contractions (``_dot_scaled``: the row's K-reduction is a
    digit dot minus a one-hot residue dot per (digit, sign) pair — no
    (M, K, N) temporary).  ``form="rows"`` keeps the pure-VPU row emulation — still
    the bit-exact reference datapath for validating the silicon and
    calibrating the statistical noise model that the quantized fast path
    (quant_matmul) uses.  ``form=None`` auto-picks the dot form; its
    scaled accumulation stays inside the rows-form int32 envelope for
    every vbl (``booth_rows.dotform_scaled_bound`` has the re-derived
    analysis).
  * ``w`` is the Booth *multiplier* operand and is constant across the whole
    grid (every (i, j) tile re-reads the same weight blocks), so its radix-4
    digits are decoded exactly once per call by ``booth_rows.booth_precode``
    and streamed in as ``(wl//2, K, N)`` planes, BlockSpec-tiled like ``w``
    itself.  The in-kernel row loop is then multiply-free (select/negate/
    shift per row).  ``bbm_matmul`` keeps the raw-code signature and
    precodes internally; ``bbm_matmul_precoded`` accepts decoded planes for
    callers whose weights are long-lived.
  * The Booth row loop (wl/2 iterations) is unrolled at trace time; each row
    materializes one (bm, bk, bn) int32 tile in VMEM.  With the default
    64x64x64 blocking that is 1 MiB live — comfortably inside the ~16 MiB
    VMEM budget together with the x/w/out tiles.
  * Accumulation is int32.  Callers must respect the documented overflow
    envelope: K * 2^(2*wl - 1 - shift) < 2^31 (asserted in ops.py).

Block shapes are (bm, bk) x (bk, bn) -> (bm, bn) with a 3-D grid over
(M/bm, N/bn, K/bk); the K axis accumulates in place (output revisited).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.booth import num_pp_rows
from ..core.faults import apply_acc_fault, apply_plane_faults
from .booth_rows import (amm_chunk_len, bbm_rows_product_precoded,
                         booth_high_value, booth_precode,
                         f32_exact_chunk_len, num_corr_rows, resolve_form,
                         scaled_trunc_rows, signed_digit, split_signed)
from .ref import amm_quantize

__all__ = ["bbm_matmul_kernel", "bbm_matmul", "bbm_matmul_coded",
           "bbm_matmul_coded_kblocks", "bbm_matmul_dynamic",
           "bbm_matmul_precoded", "bbm_matmul_scaled", "dot_scaled_chunked"]

# auto-form only: above this many int32 elements the shift > vbl residual
# branch's (M, K, N) per-product temporary stops being a fair trade against
# the tiled rows kernel, so form=None falls back to streaming there.  (The
# shift <= vbl dot form is fully contracted and needs no such gate.)  An
# explicit form="dot" is honored regardless — the caller owns the memory.
_DOT_CORR_BUDGET = 1 << 26

# the (signed digit, raw sign bit) pairs a radix-4 row can take, per BBM
# kind.  Each pair is one dense contraction of the dot form's mod-term:
# kind 0 folds the sign into the row value (the digit alone determines the
# residue), kind 1 one's-complements (the 111 "negative zero" triplet —
# digit 0, sign 1 — has residue (0 - 1) & mask, which is why it appears).
_MOD_BRANCHES = {0: ((1, 0), (2, 0), (-1, 0), (-2, 0)),
                 1: ((1, 0), (2, 0), (0, 1), (-1, 1), (-2, 1))}


def _dot_i32(x, y, *, f32_chunk: int = 0):
    """int32 contraction ``x @ y``, optionally via exact-envelope f32 gemms.

    ``f32_chunk = 0`` is the historical lowering: one s32 dot.  A positive
    ``f32_chunk`` (from ``booth_rows.f32_exact_chunk_len``) splits the
    contraction into K-chunks inside the caller's f32-exact envelope —
    every product and every partial sum is an integer of magnitude
    <= 2^24, so the f32 gemm computes the exact integer and the cast back
    to int32 is exact.  Bit-identical either way; the f32 route is what
    lets the flash-amm tile arithmetic ride the f32 matmul units
    (HIGHEST precision pins the TPU MXU to the exact f32 decomposition;
    CPU XLA ignores it).
    """
    if not f32_chunk:
        return jax.lax.dot(x, y, preferred_element_type=jnp.int32)
    k = x.shape[-1]
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    acc = None
    for lo in range(0, k, f32_chunk):
        part = jax.lax.dot(xf[:, lo:lo + f32_chunk],
                           yf[lo:lo + f32_chunk, :],
                           precision=jax.lax.Precision.HIGHEST,
                           preferred_element_type=jnp.float32
                           ).astype(jnp.int32)
        acc = part if acc is None else acc + part
    return acc


def _dot_scaled(x_s, wmag, wneg, *, wl: int, vbl: int, kind: int,
                f32_chunk: int = 0):
    """``sum_k bbm(x, w) / 2^vbl`` as pure dense contractions, int32.

    Every BBM product is ``2^vbl * M`` with
    ``M = x*bq + sum_{r<R} q_r``, ``q_r = (d_r*x - neg_r*kind) >> m_r``
    (the folded dot form).  Writing the floor as subtraction of the
    residue, a whole row's K-reduction collapses to contractions:

        sum_k q_{r,k} = [ dot(x, d_r) - kind * sum_k neg_r
                          - sum_k ((d_r*x - neg_r*kind) mod 2^m_r) ] >> m_r

    and the residue sum — the only nonlinear term — depends on ``x`` only
    through ``x mod 2^m_r`` and on the weight only through which of the
    few (digit, sign) pairs its row takes (``_MOD_BRANCHES``): a one-hot
    indicator per pair turns it into ``dot(residue_pair(x), indicator)``.
    So the whole reduction is the dominant ``x @ bq`` matmul plus a
    handful of narrow contractions per truncated row — nothing ever
    materializes an (M, K, N) intermediate, which is what lets the
    ``amm_dense`` bitexact mode run at model batch sizes in O(M*N) live
    memory.  The bracket is exactly divisible by ``2^m_r`` (it is a sum
    of ``2^m_r * q`` terms), so the shift is an exact division.

    int32-exact for chunks within ``booth_rows.amm_chunk_len(wl, vbl)``.
    x_s: (M, K) signed codes; wmag/wneg: (wl//2, K, N) digit planes.
    ``f32_chunk``: nonzero routes every contraction through ``_dot_i32``'s
    exact-envelope f32 gemms (bit-identical; the flash-amm fast path).
    """
    bq = booth_high_value(wmag, wneg, wl=wl, vbl=vbl)        # (K, N)
    acc = _dot_i32(x_s, bq, f32_chunk=f32_chunk)
    for r in range(num_corr_rows(wl, vbl)):
        m = vbl - 2 * r                   # > 0 for every correction row
        mask = (1 << m) - 1
        d = signed_digit(wmag[r], wneg[r])                   # (K, N)
        rowdot = _dot_i32(x_s, d, f32_chunk=f32_chunk)
        if kind:
            rowdot = rowdot - jnp.sum(wneg[r], axis=0,
                                      dtype=jnp.int32)[None, :]
        xm = x_s & mask                                      # (M, K)
        modsum = None
        for v, s in _MOD_BRANCHES[kind]:
            t = (v * xm - s) & mask                          # (M, K)
            ind = (d == v) if kind == 0 else (d == v) & (wneg[r] == s)
            part = _dot_i32(t, ind.astype(jnp.int32), f32_chunk=f32_chunk)
            modsum = part if modsum is None else modsum + part
        acc = acc + ((rowdot - modsum) >> m)
    return acc


def _matmul_dotform(x, wmag, wneg, *, wl: int, vbl: int, kind: int,
                    shift: int):
    """Dot-form matmul: dense contractions + exact-division row folding.

    Bit-identical to the rows kernel.  The ``shift <= vbl`` common case is
    the fully contracted ``_dot_scaled`` reduction (no (M, K, N)
    temporary); only ``shift > vbl`` — a residual floor applied per
    product, *before* the K reduction — still walks a windowed
    per-product term.  Accumulating at the ``2^-max(vbl, shift)`` scale
    keeps every partial sum inside the rows-form int32 envelope
    (``booth_rows.dotform_scaled_bound``).
    """
    _, x_s = split_signed(x, wl)
    u = max(shift - vbl, 0)       # per-product residual rescale (rare)
    if u == 0:
        acc = _dot_scaled(x_s, wmag, wneg, wl=wl, vbl=vbl, kind=kind)
    else:
        # shift > vbl: the residual floor applies per product, before
        # the K reduction — inherently per-(m, k, n)
        wq = booth_high_value(wmag, wneg, wl=wl, vbl=vbl)    # (K, N)
        q = scaled_trunc_rows(x_s[:, :, None], wmag[:, None, :, :],
                              wneg[:, None, :, :], wl=wl, vbl=vbl,
                              kind=kind)                     # (M, K, N)
        m_prod = x_s[:, :, None] * wq[None]
        if q is not None:
            m_prod = m_prod + q
        acc = jnp.sum(m_prod >> u, axis=1, dtype=jnp.int32)
    if vbl > shift:
        acc = acc << (vbl - shift)
    return acc


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "fault"))
def bbm_matmul_scaled(x, wmag, wneg, *, wl: int, vbl: int, kind: int = 0,
                      fault=None):
    """``sum_k bbm(x[m,k], w[k,n])`` as float32, any K — the amm datapath.

    The model-scale entry point behind ``amm_dense`` mode="bitexact":
    contracts K in chunks of ``booth_rows.amm_chunk_len(wl, vbl)`` so
    every chunk partial is an *exact* int32 at the ``2^-vbl`` product
    scale (``_dot_scaled``), accumulates the partials in float32 in chunk
    order, and rescales by ``2^vbl`` (a power of two: exact in float32).
    K within one chunk — every LM operating point at vbl >= wl - 3 —
    is therefore exact end to end; beyond it only the cross-chunk float32
    adds round, at relative 2^-24.  Never materializes an (M, K, N)
    intermediate for any K (the scalar closed forms do, which is what
    limited the old bitexact mode to reduced configs).

    x: (M, K) int32 codes; wmag/wneg: (wl//2, K, N) planes from
    ``booth_precode``.  Returns float32 (M, N) at full product scale.

    fault: optional ``core.faults.FaultSpec`` (static).  "plane" faults
    hit the weight digit planes *before* the chunk split (mask shape =
    the caller's (wl//2, K, N) planes, so the scalar oracle
    ``ref.amm_faulty_ref`` faults the same cells); "acc" faults XOR a
    keyed upset into each chunk's int32 partial, folded by chunk index —
    the same draws the oracle's python chunk loop makes.  ``None`` (and
    any disabled spec) traces the identical program as before.
    """
    mm, kk = x.shape
    n_rows, kk2, nn = wmag.shape
    if wmag.shape != wneg.shape or n_rows != num_pp_rows(wl) or kk != kk2:
        raise ValueError(f"digit planes {wmag.shape}/{wneg.shape} do not "
                         f"match wl={wl}, K={kk}")
    wmag, wneg = apply_plane_faults(wmag, wneg, fault, vbl=vbl)
    _, x_s = split_signed(x, wl)
    chunk = amm_chunk_len(wl, vbl)
    scale = float(1 << vbl)
    if kk <= chunk:
        acc = _dot_scaled(x_s, wmag, wneg, wl=wl, vbl=vbl, kind=kind)
        acc = apply_acc_fault(acc, fault, 0)
        return acc.astype(jnp.float32) * scale
    n_chunks = -(-kk // chunk)
    pad = n_chunks * chunk - kk
    # zero codes decode to all-zero digits (mag 0, neg 0): every padded
    # column contributes 0 to every contraction, so padding is exact
    # (plane faults were applied above, on the caller's unpadded planes —
    # padded columns are clean zeros and still contribute nothing)
    x_s = jnp.pad(x_s, ((0, 0), (0, pad)))
    wmag = jnp.pad(wmag, ((0, 0), (0, pad), (0, 0)))
    wneg = jnp.pad(wneg, ((0, 0), (0, pad), (0, 0)))
    xc = x_s.reshape(mm, n_chunks, chunk).transpose(1, 0, 2)
    wmc = wmag.reshape(n_rows, n_chunks, chunk, nn).transpose(1, 0, 2, 3)
    wnc = wneg.reshape(n_rows, n_chunks, chunk, nn).transpose(1, 0, 2, 3)

    def body(acc, xs):
        ci, xi, mi, ni = xs
        part = _dot_scaled(xi, mi, ni, wl=wl, vbl=vbl, kind=kind)
        part = apply_acc_fault(part, fault, ci)
        return acc + part.astype(jnp.float32), None

    acc, _ = jax.lax.scan(body, jnp.zeros((mm, nn), jnp.float32),
                          (jnp.arange(n_chunks), xc, wmc, wnc))
    return acc * scale


def dot_scaled_chunked(x, wmag, wneg, *, wl: int, vbl: int, kind: int,
                       f32_dots: bool = False):
    """Kernel-safe chunked ``sum_k bbm(x, w)`` — bitwise ``bbm_matmul_scaled``.

    Same contraction schedule as ``bbm_matmul_scaled`` (K chunked by
    ``amm_chunk_len``, int32-exact partials accumulated in float32 in
    chunk order, rescaled by ``2^vbl``), but built from a static python
    loop over ragged chunk slices instead of pad + ``lax.scan`` — legal
    inside a Pallas kernel body, where scan over sliced operands is not.
    The two schedules are bit-identical: padded zero codes decode to
    all-zero digit planes and contribute 0 to every contraction
    (including the kind-1 residue branch, whose indicator is gated on the
    padded ``wneg``), so ragged-final-chunk partials equal padded-chunk
    partials and the float32 adds see the same values in the same order.

    ``f32_dots=True`` additionally routes each chunk's contractions
    through the exact-envelope f32 gemms (``f32_exact_chunk_len``) — the
    flash-amm fast path; still bit-identical, falls back to s32 dots at
    operating points with no f32 envelope.

    x: (M, K) int32 codes; wmag/wneg: (wl//2, K, N) planes.  Returns
    float32 (M, N) at full product scale.
    """
    kk = x.shape[-1]
    _, x_s = split_signed(x, wl)
    chunk = amm_chunk_len(wl, vbl)
    f32_chunk = f32_exact_chunk_len(wl, vbl) if f32_dots else 0
    scale = float(1 << vbl)
    if kk <= chunk:
        return _dot_scaled(x_s, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                           f32_chunk=f32_chunk).astype(jnp.float32) * scale
    acc = None
    for lo in range(0, kk, chunk):
        part = _dot_scaled(x_s[:, lo:lo + chunk],
                           wmag[:, lo:lo + chunk],
                           wneg[:, lo:lo + chunk],
                           wl=wl, vbl=vbl, kind=kind, f32_chunk=f32_chunk)
        part = part.astype(jnp.float32)
        acc = part if acc is None else acc + part
    return acc * scale


def bbm_matmul_dynamic(a, b, *, wl: int, vbl: int, kind: int = 0,
                       fault=None):
    """Both-operands-dynamic Broken-Booth matmul — the attention entry point.

    ``bbm_matmul_scaled`` contracts quantized codes against a *precoded*
    multiplier operand: the weight-side calling convention, where the
    dynamic scale and radix-4 digit planes are derived once per parameter
    and cached (``AmmRuntime.precode``).  Attention has no weight side —
    the score product ``Q @ K^T`` and the value product ``P @ V`` multiply
    activations by activations, and both operands change every call — so
    this wrapper quantizes *both* sides per call (``ref.amm_quantize``
    dynamic-range scales, derived from this (M, K) / (K, N) slice alone:
    vmapping over batch/head axes yields per-slice scales), decodes ``b``'s
    digit planes inline, contracts through the same chunked
    digit-dot-minus-residue-dot correction (K chunked by
    ``booth_rows.amm_chunk_len`` so every intermediate stays int32-exact
    per chunk), and descales.

    a: (M, K) float, b: (K, N) float.  Returns (M, N) in ``a.dtype``,
    bit-identical to the scalar closed-form oracle ``ref.amm_dot_ref``
    (same quantizer, same chunk schedule, same descale expression).

    Deliberately not jitted as a unit (only the ``bbm_matmul_scaled``
    core is): XLA's fusion can round ``amm_quantize``'s dynamic-scale
    division differently inside a larger compiled program than op-by-op,
    so the bitwise dot-vs-oracle contract holds *per compilation
    context* — both sides of a comparison must be traced the same way,
    which the shared attention schedule guarantees and an extra jit
    boundary here would break.

    fault: optional ``core.faults.FaultSpec`` forwarded to
    ``bbm_matmul_scaled`` — hardware-fault injection on the ``b``-side
    digit planes / the chunk accumulator, oracled by
    ``ref.amm_faulty_ref`` (bit-identical under the same spec).
    """
    aq, s_a = amm_quantize(a, wl)
    bq, s_b = amm_quantize(b, wl)
    mag, neg = booth_precode(bq, wl)
    yq = bbm_matmul_scaled(aq, mag, neg, wl=wl, vbl=vbl, kind=kind,
                           fault=fault)
    return (yq * (s_a * s_b)).astype(a.dtype)


def bbm_matmul_coded(a, b_codes, s_b, *, wl: int, vbl: int, kind: int = 0):
    """Codes-in sibling of ``bbm_matmul_dynamic``: ``b`` arrives quantized.

    The int-code KV cache entry point.  ``a`` (M, K) float is quantized
    per call; ``b_codes`` (K, N) are wl-bit codes frozen at cache-write
    time with scale(s) ``s_b`` — a scalar, or an (N,) vector when columns
    were quantized in groups (the per-block K-cache scales, expanded to
    per-column by the caller).  Skipping the per-call ``b``-side
    ``amm_quantize`` is the point: that max/round/clip pass over the whole
    cache slice is the hot non-matmul cost of the dynamic entry at decode.

    When ``s_b`` equals the scale ``amm_quantize`` would derive for the
    float ``b``, this is bit-identical to ``bbm_matmul_dynamic(a, b)``
    minus the straight-through caveats: same contraction, and the descale
    ``yq * (s_a * s_b)`` broadcasts a per-column vector through the same
    float expression as the scalar.  Not jitted as a unit for the same
    per-compilation-context reason as the dynamic entry.
    """
    aq, s_a = amm_quantize(a, wl)
    mag, neg = booth_precode(jnp.asarray(b_codes, jnp.int32), wl)
    yq = bbm_matmul_scaled(aq, mag, neg, wl=wl, vbl=vbl, kind=kind)
    s_b = jnp.asarray(s_b, jnp.float32)
    if s_b.ndim == 1:
        s_b = s_b[None, :]
    return (yq * (s_a * s_b)).astype(a.dtype)


def bbm_matmul_coded_kblocks(a, b_codes, s_b, *, wl: int, vbl: int,
                             kind: int = 0, block: int):
    """``bbm_matmul_coded`` with per-K-block ``b`` scales (the PV product).

    The V cache quantizes rows in groups of ``block`` positions, so the
    contraction cannot descale once at the end: each K-block contracts as
    codes through ``bbm_matmul_scaled`` and descales by its own
    ``s_a * s_b[j]`` before the float32 combine, accumulated in block
    order (float addition order is part of the bitwise contract — with a
    single block this reduces exactly to ``bbm_matmul_coded``).  ``a``'s
    dynamic scale is derived once over the whole (M, K) slice, matching
    what the dynamic entry would compute for the same ``a``.

    a: (M, K) float; b_codes: (K, N) codes with K % block == 0;
    s_b: (K // block,) f32.
    """
    kk = b_codes.shape[0]
    if kk % block:
        raise ValueError(f"K={kk} not a multiple of block={block}")
    aq, s_a = amm_quantize(a, wl)
    b_codes = jnp.asarray(b_codes, jnp.int32)
    acc = None
    for bi, lo in enumerate(range(0, kk, block)):
        mag, neg = booth_precode(b_codes[lo:lo + block], wl)
        yq = bbm_matmul_scaled(aq[:, lo:lo + block], mag, neg,
                               wl=wl, vbl=vbl, kind=kind)
        part = yq * (s_a * s_b[bi])
        acc = part if acc is None else acc + part
    return acc.astype(a.dtype)


def bbm_matmul_kernel(x_ref, wm_ref, ws_ref, o_ref, *, wl: int, vbl: int,
                      kind: int, shift: int, n_k: int):
    """One (bm, bn) output tile; grid axis 2 streams K blocks."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                      # (bm, bk) int32, wl-bit codes
    _, x_s = split_signed(x, wl)
    a = x_s[:, :, None]                                      # (bm, bk, 1)
    # (wl//2, bk, bn) digit planes; row r broadcasts (bk, bn) against a
    prod = bbm_rows_product_precoded(a, wm_ref[...], ws_ref[...],
                                     wl=wl, vbl=vbl, kind=kind)
    # per-product rescale then reduce over the k axis of the tile
    if shift:
        prod = prod >> shift
    o_ref[...] += jnp.sum(prod, axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "shift",
                                             "bm", "bk", "bn", "interpret",
                                             "form"))
def bbm_matmul_precoded(x, wmag, wneg, *, wl: int, vbl: int, kind: int = 0,
                        shift: int = 0, bm: int = 64, bk: int = 64,
                        bn: int = 64, interpret: bool = False,
                        form: str | None = None):
    """Tiled approximate matmul on precoded weight-digit planes.

    x: (M, K) int32 codes; wmag, wneg: (wl//2, K, N) planes from
    ``booth_precode`` of the (K, N) weight code matrix.
    form: "rows" (VPU row emulation), "dot" (dense contraction + scaled
    truncated rows, on the matmul units) or None (auto: the dot form).
    Bit-identical; ``bm``/``bk``/``bn``/``interpret`` only shape the rows
    form.
    """
    mm, kk = x.shape
    n_rows, kk2, nn = wmag.shape
    if wmag.shape != wneg.shape:
        raise ValueError(f"mag/neg plane shapes differ: "
                         f"{wmag.shape} vs {wneg.shape}")
    if n_rows != num_pp_rows(wl) or kk != kk2:
        raise ValueError(f"digit planes {wmag.shape} do not match "
                         f"wl={wl}, K={kk}")
    if form is None and shift > vbl and mm * kk * nn > _DOT_CORR_BUDGET:
        # only the per-product residual floor (shift > vbl) still
        # materializes an (M, K, N) temporary; the shift <= vbl dot form
        # is fully contracted (_dot_scaled) and has no size cliff
        form = "rows"
    if resolve_form(form) == "dot":
        return _matmul_dotform(x, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                               shift=shift)
    grid = (pl.cdiv(mm, bm), pl.cdiv(nn, bn), pl.cdiv(kk, bk))
    kernel = functools.partial(bbm_matmul_kernel, wl=wl, vbl=vbl, kind=kind,
                               shift=shift, n_k=grid[2])
    plane_spec = pl.BlockSpec((n_rows, bk, bn), lambda i, j, k: (0, k, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            plane_spec,
            plane_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), jnp.int32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wmag, wneg)


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "shift",
                                             "bm", "bk", "bn", "interpret",
                                             "form"))
def bbm_matmul(x, w, *, wl: int, vbl: int, kind: int = 0, shift: int = 0,
               bm: int = 64, bk: int = 64, bn: int = 64,
               interpret: bool = False, form: str | None = None):
    """Tiled bit-exact approximate matmul.  x: (M, K) w: (K, N), int32 codes.

    Thin raw-code wrapper: precodes ``w`` once (hoisting the recode out of
    the grid, which re-reads every weight block M/bm times) and dispatches
    to ``bbm_matmul_precoded``.
    """
    wmag, wneg = booth_precode(w, wl)
    return bbm_matmul_precoded(x, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                               shift=shift, bm=bm, bk=bk, bn=bn,
                               interpret=interpret, form=form)
