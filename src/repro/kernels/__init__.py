"""Pallas TPU kernels (validated on CPU via interpret mode) + jnp oracles."""
from .bbm_matmul import (bbm_matmul_dynamic, bbm_matmul_scaled,
                         dot_scaled_chunked)
from .booth_rows import (amm_chunk_len, bbm_rows_product_dotform,
                         booth_correction, booth_high_value, booth_precode,
                         booth_value, dotform_scaled_bound,
                         f32_exact_chunk_len, resolve_form)
from .fir_kernel import (fir_bbm, fir_bbm_bank, fir_bbm_bank_precoded,
                         min_safe_shift)
from .flash_attention import FLASH_AMM_BK, FLASH_AMM_BQ, flash_attention_amm
from .ops import (bbm_matmul, bbm_matmul_precoded, fir_filterbank,
                  fir_filterbank_precoded, flash_attention, on_tpu,
                  quant_matmul)

__all__ = ["FLASH_AMM_BK", "FLASH_AMM_BQ", "amm_chunk_len", "bbm_matmul",
           "bbm_matmul_dynamic", "bbm_matmul_precoded",
           "bbm_matmul_scaled", "bbm_rows_product_dotform",
           "booth_correction", "booth_high_value", "booth_precode",
           "booth_value", "dot_scaled_chunked", "dotform_scaled_bound",
           "f32_exact_chunk_len", "fir_bbm", "fir_bbm_bank",
           "fir_bbm_bank_precoded", "fir_filterbank",
           "fir_filterbank_precoded", "flash_attention",
           "flash_attention_amm", "min_safe_shift", "on_tpu", "quant_matmul",
           "resolve_form"]
