"""Pallas TPU kernels (validated on CPU via interpret mode) + jnp oracles."""
from .fir_kernel import fir_bbm, fir_bbm_bank, min_safe_shift
from .ops import bbm_matmul, fir_filterbank, flash_attention, on_tpu, \
    quant_matmul

__all__ = ["bbm_matmul", "fir_bbm", "fir_bbm_bank", "fir_filterbank",
           "flash_attention", "min_safe_shift", "on_tpu", "quant_matmul"]
