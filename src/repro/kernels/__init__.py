"""Pallas TPU kernels (validated on CPU via interpret mode) + jnp oracles."""
from .fir_kernel import fir_bbm
from .ops import bbm_matmul, flash_attention, on_tpu, quant_matmul

__all__ = ["bbm_matmul", "fir_bbm", "flash_attention", "on_tpu", "quant_matmul"]
