"""Pallas TPU kernel: flash attention (blockwise online softmax).

The serving/training fast path for the 32k prefill shapes.  Standard
two-level blocking: grid = (batch*heads, Q blocks, KV blocks); the running
max/denominator/accumulator live in VMEM scratch across the KV axis (declared
"arbitrary" so the revisits are sequential).

Causal masking is applied at block granularity: KV blocks entirely in the
future are masked via the per-element comparison (the pure-JAX chunked
attention in models/attention.py skips them outright; the kernel keeps the
grid static).

Validated against ref.attention_ref in interpret mode over shape/dtype sweeps
(tests/test_kernels.py).  The multi-pod dry-run deliberately lowers the pure
JAX path instead (Pallas kernels do not lower to the CPU backend used for the
512-device compile check) — selected by ModelRuntime.use_pallas_attention.

Approximate attention (``flash_attention_amm``): the Broken-Booth product
*does* graft into this tile arithmetic — PR 3's identity makes every
approximate block product an exact integer dot minus a ceil(vbl/2)-row
correction, which is plain (bq, bk)-tile matmul work.  The lowering
contract: Q/K/V are quantized to wl-bit int32 codes *outside* the grid
(``ref.amm_quantize`` per (batch*head, block) — the same per-slice scales
``bbm_matmul_dynamic`` derives under ``amm_dot``'s vmap), and the kernel
takes codes + per-block scales + K's precoded radix-4 digit planes as
operands.  Each tile's score block is ``exact_dot - correction`` via the
``_dot_scaled`` branch structure (``bbm_matmul.dot_scaled_chunked``: digit
dot minus per-(digit, sign) one-hot residue dots), with the integer
accumulation completing before the online-softmax renormalization touches
it — the docs/attention.md envelope argument, per tile.  The PV product
gets the same treatment against V's inline-decoded planes; the
probability block is quantized in-tile (it exists nowhere else).  The
m/l/acc VMEM scratch scheme is unchanged from the exact kernel.  Off-TPU
the same tile step runs as a jitted XLA scan (``use_kernel=False``), and
the tile contractions ride the f32 matmul units through the exact-f32
envelope (``booth_rows.f32_exact_chunk_len``) — bit-identical to the s32
dots, and the reason flash-amm beats the chunked path on wall clock.
Routing lives in ``models.attention.attention``; bitwise equality against
the chunked-amm path is the tests/test_flash_amm.py contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bbm_matmul import dot_scaled_chunked
from .booth_rows import booth_precode
from .ref import amm_quantize

__all__ = ["flash_attention", "flash_attention_amm",
           "FLASH_AMM_BQ", "FLASH_AMM_BK"]

NEG_INF = -1e30

# flash-amm tile sizes: the chunked-amm reference must be run at the same
# blocking for the bitwise-equality contract (quantization is per block)
FLASH_AMM_BQ = 128
FLASH_AMM_BK = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, bq: int, bk: int, n_kv: int,
                 skv: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    v = v_ref[0]                                   # (bk, d)
    # zero out-of-range KV rows: the final block may be padded with
    # uninitialized memory, and 0 * NaN would poison the p @ v product.
    kv_rows = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
    kv_valid = kv_rows < skv
    k = jnp.where(kv_valid, k, 0)
    v = jnp.where(kv_valid, v, 0)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = k_pos < skv
    if causal:
        q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        live = live & (q_pos >= k_pos)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(kv_idx == n_kv - 1)
    def _done():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, H, Skv, D) -> (B, H, Sq, D).

    GQA is handled by the caller repeating KV heads (or by reshaping groups
    into the batch axis); the kernel sees matched head counts.
    """
    b, h, sq, d = q.shape
    _, _, skv, _ = k.shape
    bq = min(bq, sq)
    bk = min(bk, skv)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, skv, d)
    vf = v.reshape(b * h, skv, d)
    grid = (b * h, pl.cdiv(sq, bq), pl.cdiv(skv, bk))
    kernel = functools.partial(
        _attn_kernel, scale=1.0 / (d ** 0.5), causal=causal,
        bq=bq, bk=bk, n_kv=grid[2], skv=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)


# ------------------------------------------------------------- flash + amm
def _amm_product(af, bf, ac, bmag, bneg, s_a, s_b, *, wl: int, vbl: int,
                 kind: int):
    """One tile product through the amm datapath — ``amm_dot`` per tile.

    Replicates the straight-through composition of
    ``models.common.amm_dot`` over ``bbm_matmul_dynamic`` exactly: exact
    f32 dot, dot-form approximate product from pre-derived codes/planes/
    scales, ``exact + stop_gradient(approx - exact)``.  The only
    difference is *where* the pieces were computed (codes and scales
    arrive as operands instead of being derived in-call) and that the
    integer contractions take the exact-f32-envelope fast path
    (``f32_dots=True``) — both bit-preserving.
    """
    exact = af @ bf
    yq = dot_scaled_chunked(ac, bmag, bneg, wl=wl, vbl=vbl, kind=kind,
                            f32_dots=True)
    approx = (yq * (s_a * s_b)).astype(af.dtype)
    return exact + jax.lax.stop_gradient(approx - exact)


def _amm_tile_step(m_prev, l_prev, acc_prev, qf, kf, vf, qc, kmag, kneg, vc,
                   s_q, s_k, s_v, q_idx, kv_idx, *, wl: int, vbl: int,
                   kind: int, causal: bool, bq: int, bk: int, kv_len: int):
    """One (q-block, kv-block) online-softmax step on the amm datapath.

    The single source of truth for the flash-amm tile arithmetic: the
    Pallas kernel body and the off-TPU XLA scan both call this, so the
    two lowerings cannot drift.  Operand shapes (one tile):
    qf (bq, d) f32 pre-scaled queries, kf/vf (bk, d) f32, qc (bq, d) i32
    codes, kmag/kneg (wl//2, d, bk) K digit planes, vc (bk, d) i32 codes,
    s_q/s_k/s_v scalar block scales; m/l/acc are (bq, 1)/(bq, 1)/(bq, d).

    Float op order is copied from ``chunked_attention``'s kv_block —
    score product, mask, max, exp, renormalize, PV product, accumulate —
    because bitwise equality with that path is the contract.  The P block
    is quantized here (it exists only inside the step) and V's planes are
    decoded inline from its codes; both are elementwise and tile-local.
    """
    s = _amm_product(qf, kf.swapaxes(-1, -2), qc, kmag, kneg, s_q, s_k,
                     wl=wl, vbl=vbl, kind=kind)             # (bq, bk)
    q_pos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = k_pos < kv_len
    if causal:
        live = live & (q_pos >= k_pos)
    s = jnp.where(live, s, NEG_INF)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    pc, s_p = amm_quantize(p, wl)
    vmag, vneg = booth_precode(vc, wl)
    pv = _amm_product(p, vf, pc, vmag, vneg, s_p, s_v,
                      wl=wl, vbl=vbl, kind=kind)            # (bq, d)
    acc_new = acc_prev * alpha + pv
    return m_new, l_new, acc_new


def _attn_amm_kernel(qf_ref, kf_ref, vf_ref, qc_ref, km_ref, kn_ref, vc_ref,
                     qs_ref, ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                     wl: int, vbl: int, kind: int, causal: bool, bq: int,
                     bk: int, n_kv: int, kv_len: int):
    """Pallas body: ``_amm_tile_step`` + the exact kernel's scratch scheme."""
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    m, l, acc = _amm_tile_step(
        m_scr[...], l_scr[...], acc_scr[...],
        qf_ref[0], kf_ref[0], vf_ref[0], qc_ref[0], km_ref[0], kn_ref[0],
        vc_ref[0], qs_ref[0, 0], ks_ref[0, 0], vs_ref[0, 0],
        pl.program_id(1), kv_idx, wl=wl, vbl=vbl, kind=kind, causal=causal,
        bq=bq, bk=bk, kv_len=kv_len)
    m_scr[...] = m
    l_scr[...] = l
    acc_scr[...] = acc

    @pl.when(kv_idx == n_kv - 1)
    def _done():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "causal",
                                             "bq", "bk", "kv_len",
                                             "interpret"))
def _flash_amm_pallas(qf, kf, vf, qc, kmag, kneg, vc, qs, ks, vs, *,
                      wl: int, vbl: int, kind: int, causal: bool, bq: int,
                      bk: int, kv_len: int, interpret: bool):
    """Pallas dispatch: grid (batch*heads, Q blocks, KV blocks)."""
    bh, sqp, d = qf.shape
    _, skvp, _ = kf.shape
    nr = kmag.shape[1]
    nq, nk = sqp // bq, skvp // bk
    kmag = kmag.reshape(bh, nr, d, nk * bk)
    kneg = kneg.reshape(bh, nr, d, nk * bk)
    grid = (bh, nq, nk)
    kernel = functools.partial(_attn_amm_kernel, wl=wl, vbl=vbl, kind=kind,
                               causal=causal, bq=bq, bk=bk, n_kv=nk,
                               kv_len=kv_len)
    plane_spec = pl.BlockSpec((1, nr, d, bk), lambda g, i, j: (g, 0, 0, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),   # qf
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),   # kf
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),   # vf
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),   # qc
            plane_spec,                                            # kmag
            plane_spec,                                            # kneg
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),   # vc
            pl.BlockSpec((1, 1), lambda g, i, j: (g, i)),          # qs
            pl.BlockSpec((1, 1), lambda g, i, j: (g, j)),          # ks
            pl.BlockSpec((1, 1), lambda g, i, j: (g, j)),          # vs
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, qc, kmag, kneg, vc, qs, ks, vs)


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "causal",
                                             "bq", "bk", "kv_len"))
def _flash_amm_xla(qf, kf, vf, qc, kmag, kneg, vc, qs, ks, vs, *,
                   wl: int, vbl: int, kind: int, causal: bool, bq: int,
                   bk: int, kv_len: int):
    """Off-TPU lowering of the same tile step: vmap over (batch*heads,
    Q blocks), ``lax.scan`` over KV blocks — one fused XLA program, no
    per-block score materialization, and bit-identical to the kernel (the
    tile arithmetic is shared; only the loop plumbing differs)."""
    bh, sqp, d = qf.shape
    _, skvp, _ = kf.shape
    nq, nk = sqp // bq, skvp // bk
    qfb = qf.reshape(bh, nq, bq, d)
    qcb = qc.reshape(bh, nq, bq, d)
    kfb = kf.reshape(bh, nk, bk, d)
    vfb = vf.reshape(bh, nk, bk, d)
    vcb = vc.reshape(bh, nk, bk, d)
    kmb = kmag.transpose(0, 3, 1, 2, 4)        # (bh, nk, nr, d, bk)
    knb = kneg.transpose(0, 3, 1, 2, 4)

    def q_block(qi, qf_i, qc_i, qs_i, kfh, vfh, kmh, knh, vch, ksh, vsh):
        init = (jnp.full((bq, 1), NEG_INF, jnp.float32),
                jnp.zeros((bq, 1), jnp.float32),
                jnp.zeros((bq, d), jnp.float32))

        def body(carry, inp):
            ki, kf_j, vf_j, km_j, kn_j, vc_j, ks_j, vs_j = inp
            carry = _amm_tile_step(*carry, qf_i, kf_j, vf_j, qc_i, km_j,
                                   kn_j, vc_j, qs_i, ks_j, vs_j, qi, ki,
                                   wl=wl, vbl=vbl, kind=kind, causal=causal,
                                   bq=bq, bk=bk, kv_len=kv_len)
            return carry, None

        (m, l, acc), _ = jax.lax.scan(
            body, init, (jnp.arange(nk), kfh, vfh, kmh, knh, vch, ksh, vsh))
        return acc / jnp.maximum(l, 1e-30)

    per_head = jax.vmap(
        q_block, in_axes=(0, 0, 0, 0) + (None,) * 7)
    out = jax.vmap(per_head, in_axes=(None, 0, 0, 0) + (0,) * 7)(
        jnp.arange(nq), qfb, qcb, qs, kfb, vfb, kmb, knb, vcb, ks, vs)
    return out.reshape(bh, sqp, d)


def flash_attention_amm(q, k, v, *, wl: int, vbl: int, kind: int,
                        causal: bool = True, bq: int = FLASH_AMM_BQ,
                        bk: int = FLASH_AMM_BK, use_kernel=None,
                        interpret=None):
    """Flash attention on the Broken-Booth datapath.  (B, H, S, D) in/out.

    q: (B, H, Sq, D); k, v: (B, H, Skv, D) with matched head counts (the
    caller repeats KV heads for GQA, as for ``flash_attention``).
    wl/vbl/kind: the dot-form lowering parameters
    (``AmmRuntime.attn_lowering``).  use_kernel: None picks the Pallas
    kernel on TPU and the fused XLA scan elsewhere; both run the shared
    ``_amm_tile_step``.  interpret: kernel-path interpret mode (None:
    interpret off-TPU — CPU CI runs the kernel this way).

    Bit-identical to ``chunked_attention(..., bq, bk, amm)`` at matched
    head counts and tile sizes: the decode phase here (this wrapper, not
    the grid) quantizes Q/K/V per (batch*head, block) with
    ``ref.amm_quantize`` — the same slices, hence the same dynamic-range
    scales, that ``amm_dot``'s vmapped ``bbm_matmul_dynamic`` derives
    per kv-block on the chunked path — and precodes K's digit planes
    once for the whole grid (every q-block revisits them).  Deliberately
    not jitted as a unit, mirroring ``bbm_matmul_dynamic``: the quantize
    runs op-by-op so the per-compilation-context bitwise contract against
    the chunked path holds.
    """
    b, h, sq, d = q.shape
    _, _, skv, _ = k.shape
    bq = min(bq, sq)
    bk = min(bk, skv)
    nq, nk = -(-sq // bq), -(-skv // bk)
    pad_q = nq * bq - sq
    pad_k = nk * bk - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    bh = b * h
    # the chunked path scales queries *before* quantization (q_block);
    # padded rows/cols are zeros there too, so scales match exactly
    qf = q.reshape(bh, nq * bq, d).astype(jnp.float32) * (1.0 / d ** 0.5)
    kf = k.reshape(bh, nk * bk, d).astype(jnp.float32)
    vf = v.reshape(bh, nk * bk, d).astype(jnp.float32)
    quant = jax.vmap(jax.vmap(lambda t: amm_quantize(t, wl)))
    qc, qs = quant(qf.reshape(bh, nq, bq, d))
    kc, ks = quant(kf.reshape(bh, nk, bk, d))   # == quantize of k^T blocks
    vc, vs = quant(vf.reshape(bh, nk, bk, d))
    qc = qc.reshape(bh, nq * bq, d)
    vc = vc.reshape(bh, nk * bk, d)
    # K's radix-4 digit planes, decoded once per call over the k^T code
    # blocks: (wl//2, bh, nk, d, bk) -> (bh, wl//2, d, nk, bk)
    kmag, kneg = booth_precode(kc.transpose(0, 1, 3, 2), wl)
    kmag = kmag.transpose(1, 0, 3, 2, 4)
    kneg = kneg.transpose(1, 0, 3, 2, 4)
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        nr = kmag.shape[1]
        out = _flash_amm_pallas(
            qf, kf, vf, qc,
            kmag.reshape(bh, nr, d, nk * bk),
            kneg.reshape(bh, nr, d, nk * bk),
            vc, qs, ks, vs, wl=wl, vbl=vbl, kind=kind, causal=causal,
            bq=bq, bk=bk, kv_len=skv, interpret=interpret)
    else:
        out = _flash_amm_xla(
            qf, kf, vf, qc, kmag, kneg, vc, qs, ks, vs, wl=wl, vbl=vbl,
            kind=kind, causal=causal, bq=bq, bk=bk, kv_len=skv)
    return out[:, :sq].reshape(b, h, sq, d).astype(q.dtype)
