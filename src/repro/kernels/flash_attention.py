"""Pallas TPU kernel: flash attention (blockwise online softmax).

The serving/training fast path for the 32k prefill shapes.  Standard
two-level blocking: grid = (batch*heads, Q blocks, KV blocks); the running
max/denominator/accumulator live in VMEM scratch across the KV axis (declared
"arbitrary" so the revisits are sequential).

Causal masking is applied at block granularity: KV blocks entirely in the
future are masked via the per-element comparison (the pure-JAX chunked
attention in models/attention.py skips them outright; the kernel keeps the
grid static).

Validated against ref.attention_ref in interpret mode over shape/dtype sweeps
(tests/test_kernels.py).  The multi-pod dry-run deliberately lowers the pure
JAX path instead (Pallas kernels do not lower to the CPU backend used for the
512-device compile check) — selected by ModelRuntime.use_pallas_attention.

Approximate attention: this kernel has NO amm lowering — its score and
value products are exact f32 dots fused with the online softmax, and the
Broken-Booth product cannot be grafted in without rewriting the tile
arithmetic around integer codes.  When ``AmmConfig.apply_to`` routes
attention through the approximate datapath, ``models.attention.attention``
falls back to the pure-JAX chunked path (whose per-block products are the
amm hook points) regardless of ``use_pallas`` — the fallback rules and the
envelope argument live in docs/attention.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, bq: int, bk: int, n_kv: int,
                 skv: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    v = v_ref[0]                                   # (bk, d)
    # zero out-of-range KV rows: the final block may be padded with
    # uninitialized memory, and 0 * NaN would poison the p @ v product.
    kv_rows = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
    kv_valid = kv_rows < skv
    k = jnp.where(kv_valid, k, 0)
    v = jnp.where(kv_valid, v, 0)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = k_pos < skv
    if causal:
        q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        live = live & (q_pos >= k_pos)
    s = jnp.where(live, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_cur

    @pl.when(kv_idx == n_kv - 1)
    def _done():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, H, Skv, D) -> (B, H, Sq, D).

    GQA is handled by the caller repeating KV heads (or by reshaping groups
    into the batch axis); the kernel sees matched head counts.
    """
    b, h, sq, d = q.shape
    _, _, skv, _ = k.shape
    bq = min(bq, sq)
    bk = min(bk, skv)
    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * h, skv, d)
    vf = v.reshape(b * h, skv, d)
    grid = (b * h, pl.cdiv(sq, bq), pl.cdiv(skv, bk))
    kernel = functools.partial(
        _attn_kernel, scale=1.0 / (d ** 0.5), causal=causal,
        bq=bq, bk=bk, n_kv=grid[2], skv=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)
