"""Shared Broken-Booth row arithmetic for the Pallas kernels, split into a
decode phase and an accumulate phase.

Hardware Booth multipliers recode the multiplier operand exactly once per
product; in the FIR filterbank and ``bbm_matmul`` one operand — the tap
bank / weight matrix — is *constant* across samples, time blocks and
requests, so its radix-4 digits never change.  The split mirrors that:

  ``booth_precode(bu, wl)``
      decode phase: unsigned wl-bit codes -> per-row digit planes
      ``(mag, neg)``, each of shape ``(wl//2,) + bu.shape``.  ``mag`` is the
      digit magnitude in {0, 1, 2}; ``neg`` is the raw ``b_{2r+1}`` bit —
      the hardware S/sign flag (the 111 "negative zero" triplet has
      ``mag = 0, neg = 1``, which Type1 truncation exposes).  Computed once
      per bank, outside the kernel grid.

  ``bbm_rows_product_precoded(a_s, mag, neg, ...)``
      accumulate phase.  On TPU it is multiply-free: digits are in
      {-2,-1,0,1,2}, so each row contribution is a select among
      ``{0, a_s, a_s << 1}`` with a negate — shift/select/add only, which
      is what the silicon's partial product generators do and what the VPU
      likes (32-bit multiplies are multi-pass there, selects are not).
      Off-TPU (XLA CPU, the Pallas interpreter) the same planes feed a
      one-multiply-per-row form instead, because there ``d * a_s`` is a
      single fast vector op and a select chain is three.  Both forms are
      bit-identical; the ``(x >> m) << m`` truncation (the paper's VBL
      nullification; floor toward -inf for two's complement) is unchanged.

The row planes are stacked on a *leading* axis so kernel BlockSpecs keep
the large dimensions last (TPU lane/sublane friendly): a ``(C, taps)`` bank
precodes to ``(wl//2, C, taps)`` planes tiled exactly like the bank itself.

``bbm_rows_product`` is the raw-code wrapper (decode + accumulate in one
call) kept for callers that do not hoist the recode.  Everything is
resolved at trace time: the row loop is unrolled over the ``wl/2`` radix-4
rows and the per-row mask widths are Python ints, so both phases are safe
to call from inside a Pallas kernel body as well as from plain jitted code.
Bit-exact to the closed forms in ``core.bbm`` (``bbm_type0`` / ``bbm_type1``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.booth import num_pp_rows

__all__ = ["bbm_rows_product", "bbm_rows_product_precoded", "booth_precode",
           "split_signed"]


def split_signed(x, wl: int):
    """(unsigned wl-bit view, signed reinterpretation) of int32 codes."""
    mask = (1 << wl) - 1
    sign = 1 << (wl - 1)
    xu = x & mask
    return xu, jnp.where(xu >= sign, xu - (1 << wl), xu)


def booth_precode(bu, wl: int):
    """Decode phase: radix-4 digit planes of unsigned wl-bit codes ``bu``.

    Returns ``(mag, neg)`` int32 arrays of shape ``(wl//2,) + bu.shape``:
    ``mag[r]`` the magnitude of Booth digit r in {0, 1, 2} and ``neg[r]``
    the raw ``b_{2r+1}`` sign bit.  The signed digit is ``d = mag`` when
    ``neg == 0`` and ``d = -mag`` when ``neg == 1`` (the 111 triplet gives
    ``mag = 0, neg = 1``).  Call once per constant operand and feed the
    planes to ``bbm_rows_product_precoded``.
    """
    bu = jnp.asarray(bu, jnp.int32) & ((1 << wl) - 1)
    mags, negs = [], []
    prev_hi = None
    for r in range(num_pp_rows(wl)):
        # booth digit of b for row r: d = -2*b_hi + b_mid + b_lo
        b_hi = (bu >> (2 * r + 1)) & 1
        b_mid = (bu >> (2 * r)) & 1
        b_lo = jnp.zeros_like(b_mid) if r == 0 else prev_hi
        prev_hi = b_hi
        d = -2 * b_hi + b_mid + b_lo
        mags.append(jnp.abs(d))
        negs.append(b_hi)
    return jnp.stack(mags), jnp.stack(negs)


def bbm_rows_product_precoded(a_s, mag, neg, *, wl: int, vbl: int, kind: int,
                              multiply_free: bool | None = None):
    """Accumulate phase: Broken-Booth product from precoded digit planes.

    ``a_s`` is a signed int32 array; ``mag[r]`` / ``neg[r]`` must broadcast
    against it (planes from ``booth_precode``).  Bit-identical to
    ``core.bbm.bbm_mul`` for in-range operands; ``vbl = 0`` reduces both
    kinds to the exact Booth product.

    ``multiply_free`` picks the row-contribution form (same values either
    way, decided at trace time):

      True   select among ``{0, a_s, a_s << 1}`` + negate — the silicon
             partial-product generator, and the fast form on the TPU VPU,
             where a 32-bit multiply is multi-pass and a select is not.
      False  one ``d * a_s`` multiply per row — the fast form everywhere
             XLA lowers to real vector ISAs (CPU, the interpreter), where
             an int32 multiply is a single op and the select chain is
             three.
      None   auto: multiply-free on TPU backends, multiply elsewhere.
    """
    if multiply_free is None:
        multiply_free = jax.default_backend() == "tpu"
    a2 = a_s << 1                         # the shared "2A" generate
    prod = None
    for r in range(num_pp_rows(wl)):
        m_r = mag[r]
        s_r = neg[r]
        m = max(0, vbl - 2 * r)           # bits nullified in this row
        if kind == 0:
            if multiply_free:
                pos = jnp.where(m_r == 2, a2, jnp.where(m_r == 1, a_s, 0))
                rows = jnp.where(s_r == 1, -pos, pos)
            else:
                # fold the sign into the (small) digit plane: one full-size
                # multiply per row, no full-size select at all
                rows = jnp.where(s_r == 1, -m_r, m_r) * a_s
            contrib = (rows >> m) << m    # floor for two's complement
        else:
            if multiply_free:
                pos = jnp.where(m_r == 2, a2, jnp.where(m_r == 1, a_s, 0))
            else:
                pos = m_r * a_s
            rows = jnp.where(s_r == 1, -pos - 1, pos)
            contrib = (rows >> m) << m
            if m == 0:                    # S dot survives only at m == 0
                contrib = contrib + s_r
        term = contrib << (2 * r)
        prod = term if prod is None else prod + term
    return prod


def bbm_rows_product(a_s, bu, *, wl: int, vbl: int, kind: int):
    """Broken-Booth product of signed ``a_s`` and unsigned wl-bit ``bu``.

    Raw-code wrapper: decodes ``bu`` then accumulates, for callers whose
    multiplier operand is not constant (or not worth hoisting).  ``a_s``
    and ``bu`` are int32 arrays with broadcast-compatible shapes; the
    result has the broadcast shape.  Bit-identical to
    ``core.bbm.bbm_mul(a, b, wl, vbl, kind)`` for in-range operands.
    """
    mag, neg = booth_precode(bu, wl)
    return bbm_rows_product_precoded(a_s, mag, neg, wl=wl, vbl=vbl,
                                     kind=kind)
