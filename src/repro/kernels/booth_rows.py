"""Shared Broken-Booth row arithmetic for the Pallas kernels, split into a
decode phase and an accumulate phase.

Hardware Booth multipliers recode the multiplier operand exactly once per
product; in the FIR filterbank and ``bbm_matmul`` one operand — the tap
bank / weight matrix — is *constant* across samples, time blocks and
requests, so its radix-4 digits never change.  The split mirrors that:

  ``booth_precode(bu, wl)``
      decode phase: unsigned wl-bit codes -> per-row digit planes
      ``(mag, neg)``, each of shape ``(wl//2,) + bu.shape``.  ``mag`` is the
      digit magnitude in {0, 1, 2}; ``neg`` is the raw ``b_{2r+1}`` bit —
      the hardware S/sign flag (the 111 "negative zero" triplet has
      ``mag = 0, neg = 1``, which Type1 truncation exposes).  Computed once
      per bank, outside the kernel grid.

  ``bbm_rows_product_precoded(a_s, mag, neg, ...)``
      accumulate phase.  On TPU it is multiply-free: digits are in
      {-2,-1,0,1,2}, so each row contribution is a select among
      ``{0, a_s, a_s << 1}`` with a negate — shift/select/add only, which
      is what the silicon's partial product generators do and what the VPU
      likes (32-bit multiplies are multi-pass there, selects are not).
      Off-TPU (XLA CPU, the Pallas interpreter) the same planes feed a
      one-multiply-per-row form instead, because there ``d * a_s`` is a
      single fast vector op and a select chain is three.  Both forms are
      bit-identical; the ``(x >> m) << m`` truncation (the paper's VBL
      nullification; floor toward -inf for two's complement) is unchanged.

The row planes are stacked on a *leading* axis so kernel BlockSpecs keep
the large dimensions last (TPU lane/sublane friendly): a ``(C, taps)`` bank
precodes to ``(wl//2, C, taps)`` planes tiled exactly like the bank itself.

``bbm_rows_product`` is the raw-code wrapper (decode + accumulate in one
call) kept for callers that do not hoist the recode.  Everything is
resolved at trace time: the row loop is unrolled over the ``wl/2`` radix-4
rows and the per-row mask widths are Python ints, so both phases are safe
to call from inside a Pallas kernel body as well as from plain jitted code.
Bit-exact to the closed forms in ``core.bbm`` (``bbm_type0`` / ``bbm_type1``).

Dot form (the exact-product decomposition): clearing the low ``m`` bits of
a two's-complement value is subtraction of its low bits,
``(p >> m) << m  ==  p - (p & (2^m - 1))``, so every truncated Booth row is
``d_r*A - ((d_r*A) mod 2^m_r)`` and the whole Broken-Booth product
collapses to

    bbm(a, b)  ==  a_s * b_s  -  correction(a mod 2^vbl, digit planes)

where the dominant ``a_s * b_s`` term is an *exact* multiply — so a sum of
BBM products (FIR tap loop, matmul K axis) is one dense integer
contraction on the hardware's native matmul units plus a narrow correction
built entirely from masks on the low ``vbl`` bits of ``a``
(``booth_correction``; only the ``ceil(vbl/2)`` rows with a nonzero break
column participate).  ``bbm_rows_product_dotform`` is the per-element form
of that identity — the third bit-exact accumulate form.

The kernels use the *folded* equivalent: the correction's own linear term
``dot(a mod 2^vbl, h)`` is itself a dense contraction, and folding it back
in shows every BBM product is divisible by ``2^vbl`` —

    bbm(a, b) == 2^vbl * [ a*bq + sum_{r<R} ((d_r*a - neg_r*kind) >> m_r) ]

with ``bq = booth_high_value`` the truncation-surviving digit value.
Accumulating the bracketed scale keeps the dot form inside the rows-form
int32 envelope for every vbl (``dotform_scaled_bound`` carries the
re-derived analysis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.booth import num_pp_rows

__all__ = ["amm_chunk_len", "bbm_rows_product", "bbm_rows_product_precoded",
           "bbm_rows_product_dotform", "booth_correction",
           "booth_high_value", "booth_precode", "booth_precode_faulty",
           "booth_value", "dotform_scaled_bound", "f32_exact_chunk_len",
           "num_corr_rows", "resolve_form", "scaled_trunc_rows",
           "signed_digit", "split_signed"]


def split_signed(x, wl: int):
    """(unsigned wl-bit view, signed reinterpretation) of int32 codes."""
    mask = (1 << wl) - 1
    sign = 1 << (wl - 1)
    xu = x & mask
    return xu, jnp.where(xu >= sign, xu - (1 << wl), xu)


def booth_precode(bu, wl: int):
    """Decode phase: radix-4 digit planes of unsigned wl-bit codes ``bu``.

    Returns ``(mag, neg)`` int32 arrays of shape ``(wl//2,) + bu.shape``:
    ``mag[r]`` the magnitude of Booth digit r in {0, 1, 2} and ``neg[r]``
    the raw ``b_{2r+1}`` sign bit.  The signed digit is ``d = mag`` when
    ``neg == 0`` and ``d = -mag`` when ``neg == 1`` (the 111 triplet gives
    ``mag = 0, neg = 1``).  Call once per constant operand and feed the
    planes to ``bbm_rows_product_precoded``.
    """
    bu = jnp.asarray(bu, jnp.int32) & ((1 << wl) - 1)
    mags, negs = [], []
    prev_hi = None
    for r in range(num_pp_rows(wl)):
        # booth digit of b for row r: d = -2*b_hi + b_mid + b_lo
        b_hi = (bu >> (2 * r + 1)) & 1
        b_mid = (bu >> (2 * r)) & 1
        b_lo = jnp.zeros_like(b_mid) if r == 0 else prev_hi
        prev_hi = b_hi
        d = -2 * b_hi + b_mid + b_lo
        mags.append(jnp.abs(d))
        negs.append(b_hi)
    return jnp.stack(mags), jnp.stack(negs)


def booth_precode_faulty(bu, wl: int, fault=None, *, vbl: int = 0):
    """Decode phase with hardware faults injected into the digit planes.

    ``booth_precode`` followed by ``core.faults.apply_plane_faults`` —
    the injection hook every consumer of precoded planes shares, so the
    dot-form datapath, the scalar oracle and a faulted ``PrecodedBank``
    all derive *the same* faulted planes from the same ``FaultSpec``
    (keyed masks depend only on the spec and the plane shape).  A
    ``None``/disabled/non-"plane" spec returns the clean decode
    bit-identically.  ``vbl`` scopes ``rows="corr"`` faults to the
    truncated correction rows of the operating point.
    """
    from ..core.faults import apply_plane_faults
    mag, neg = booth_precode(bu, wl)
    return apply_plane_faults(mag, neg, fault, vbl=vbl)


def bbm_rows_product_precoded(a_s, mag, neg, *, wl: int, vbl: int, kind: int,
                              multiply_free: bool | None = None):
    """Accumulate phase: Broken-Booth product from precoded digit planes.

    ``a_s`` is a signed int32 array; ``mag[r]`` / ``neg[r]`` must broadcast
    against it (planes from ``booth_precode``).  Bit-identical to
    ``core.bbm.bbm_mul`` for in-range operands; ``vbl = 0`` reduces both
    kinds to the exact Booth product.

    ``multiply_free`` picks the row-contribution form (same values either
    way, decided at trace time):

      True   select among ``{0, a_s, a_s << 1}`` + negate — the silicon
             partial-product generator, and the fast form on the TPU VPU,
             where a 32-bit multiply is multi-pass and a select is not.
      False  one ``d * a_s`` multiply per row — the fast form everywhere
             XLA lowers to real vector ISAs (CPU, the interpreter), where
             an int32 multiply is a single op and the select chain is
             three.
      None   auto: multiply-free on TPU backends, multiply elsewhere.
    """
    if multiply_free is None:
        multiply_free = jax.default_backend() == "tpu"
    # the shared "2A" generate feeds only the select form; the multiply
    # form folds the digit into the (small) plane and never reads it
    a2 = a_s << 1 if multiply_free else None
    prod = None
    for r in range(num_pp_rows(wl)):
        m_r = mag[r]
        s_r = neg[r]
        m = max(0, vbl - 2 * r)           # bits nullified in this row
        if kind == 0:
            if multiply_free:
                pos = jnp.where(m_r == 2, a2, jnp.where(m_r == 1, a_s, 0))
                rows = jnp.where(s_r == 1, -pos, pos)
            else:
                # fold the sign into the (small) digit plane: one full-size
                # multiply per row, no full-size select at all
                rows = signed_digit(m_r, s_r) * a_s
            contrib = (rows >> m) << m    # floor for two's complement
        else:
            if multiply_free:
                pos = jnp.where(m_r == 2, a2, jnp.where(m_r == 1, a_s, 0))
            else:
                pos = m_r * a_s
            rows = jnp.where(s_r == 1, -pos - 1, pos)
            contrib = (rows >> m) << m
            if m == 0:                    # S dot survives only at m == 0
                contrib = contrib + s_r
        term = contrib << (2 * r)
        prod = term if prod is None else prod + term
    return prod


def signed_digit(mag_r, neg_r):
    """Signed Booth digit of one row plane: ``d = -mag`` when ``neg``.

    The single place the (mag, neg) encoding is turned back into a signed
    digit — every form (value reconstruction, correction, dot kernels)
    goes through here, so an encoding change has one site to touch.
    """
    return jnp.where(neg_r == 1, -mag_r, mag_r)


def num_corr_rows(wl: int, vbl: int) -> int:
    """Rows whose break column is nonzero: only they feed the correction.

    Row r nullifies ``m_r = max(0, vbl - 2r)`` bits, so rows with
    ``2r >= vbl`` contribute nothing; ``vbl = 0`` means no correction at
    all (the exact Booth product).
    """
    return min(num_pp_rows(wl), (vbl + 1) // 2)


def booth_value(mag, neg, *, wl: int):
    """Signed multiplier value reconstructed from its digit planes.

    ``sum_r d_r * 4^r == to_signed(b, wl)`` — the radix-4 recode is exact —
    so precoded callers never need the raw codes to form the dense
    contraction operand of the dot form.  Bank-sized work (tiny next to
    the signal), safe inside jit.
    """
    val = None
    for r in range(num_pp_rows(wl)):
        term = signed_digit(mag[r], neg[r]) << (2 * r)
        val = term if val is None else val + term
    return val


def booth_correction(a_s, mag, neg, *, wl: int, vbl: int, kind: int):
    """Low-bit correction ``c >= 0`` with ``bbm(a, b) == a_s*b_s - c``.

    Derivation: ``(p >> m) << m == p - (p & (2^m - 1))`` for two's
    complement, so per row

      Type0:  trunc_r = d_r*A - ((d_r*A) & mask_r)
      Type1:  row_r   = d_r*A - neg_r          (one's complement + S dot)
              trunc_r + sdot_r = d_r*A - [((d_r*A - neg_r) & mask_r)
                                          + neg_r]   for m_r > 0

    and ``sum_r d_r*A*4^r`` is the exact product.  Every masked term
    depends only on the low ``m_r <= vbl`` bits of ``A``, so the whole
    correction runs on ``a_s & (2^vbl - 1)`` — narrow masks and adds, no
    wide arithmetic.  ``vbl = 0`` returns the all-zero correction.

    ``mag[r]`` / ``neg[r]`` must broadcast against ``a_s`` exactly as in
    ``bbm_rows_product_precoded``; the result has the broadcast shape.
    """
    a_low = a_s & ((1 << vbl) - 1)        # nonneg, < 2^vbl: narrow products
    corr = None
    for r in range(num_corr_rows(wl, vbl)):
        m = vbl - 2 * r                   # > 0 for every correction row
        mask = (1 << m) - 1
        rows = signed_digit(mag[r], neg[r]) * a_low
        if kind == 0:
            term = rows & mask
        else:
            # the 111 "negative zero" triplet (mag 0, neg 1) lands here
            # too: ((0 - 1) & mask) + 1 == 2^m, the dropped all-ones row
            term = ((rows - neg[r]) & mask) + neg[r]
        t = term << (2 * r)
        corr = t if corr is None else corr + t
    if corr is None:
        shape = jnp.broadcast_shapes(jnp.shape(a_s), jnp.shape(mag[0]))
        corr = jnp.zeros(shape, jnp.int32)
    return corr


def bbm_rows_product_dotform(a_s, mag, neg, *, wl: int, vbl: int, kind: int):
    """Third bit-exact accumulate form: exact product minus correction.

    ``a_s * booth_value(planes) - booth_correction(...)`` — the
    per-element statement of the dot-form identity.  Same contract as
    ``bbm_rows_product_precoded`` (bit-identical to ``core.bbm.bbm_mul``);
    the payoff comes when the exact term is *summed* before the correction
    (FIR tap loop, matmul K axis): the sum is then one dense contraction
    on the matmul units (see the kernel dot forms and
    ``dotform_scaled_bound``).
    """
    b_s = booth_value(mag, neg, wl=wl)
    return a_s * b_s - booth_correction(a_s, mag, neg, wl=wl, vbl=vbl,
                                        kind=kind)


def booth_high_value(mag, neg, *, wl: int, vbl: int):
    """Truncation-surviving digit value, pre-divided by ``2^vbl``.

    The rows with a nonzero break column (r < R) lose their low bits to
    the VBL nullification; the rows above survive intact and their summed
    weight ``sum_{r >= R} d_r * 4^r`` is divisible by ``2^vbl`` (because
    ``2R >= vbl``).  Returns ``bq = sum_{r >= R} d_r << (2r - vbl)`` — the
    integer the dot form contracts the *full* signal against.  ``vbl = 0``
    reduces to ``booth_value`` (the exact multiplier).
    """
    r0 = num_corr_rows(wl, vbl)
    bq = None
    for r in range(r0, num_pp_rows(wl)):
        term = signed_digit(mag[r], neg[r]) << (2 * r - vbl)
        bq = term if bq is None else bq + term
    if bq is None:
        bq = jnp.zeros(jnp.shape(mag[0]), jnp.int32)
    return bq


def scaled_trunc_rows(a_s, mag, neg, *, wl: int, vbl: int, kind: int):
    """``Q = sum_{r<R} ((d_r*a - neg_r*kind) >> m_r)`` — the folded dot
    form's truncated-row term, at the ``2^-vbl`` product scale.

    The one implementation of the per-row truncation semantics (including
    Type1's ``- neg_r`` and the negative-zero 111 triplet) shared by every
    dot-form kernel; ``mag[r]`` / ``neg[r]`` broadcast against ``a_s``.
    Returns ``None`` when no row is truncated (``vbl = 0``).
    """
    q = None
    for r in range(num_corr_rows(wl, vbl)):
        rowp = signed_digit(mag[r], neg[r]) * a_s
        if kind == 1:
            rowp = rowp - neg[r]
        qr = rowp >> (vbl - 2 * r)
        q = qr if q is None else q + qr
    return q


def dotform_scaled_bound(k: int, wl: int, vbl: int, shift: int) -> int:
    """Worst-case |accumulator| of the dot form — the re-derived envelope.

    The naive reading of "accumulate exact products, then subtract the
    correction" overflows int32 long before the rows form does (the raw
    ``sum_k a*b`` is ``2^vbl`` larger than the truncated sum).  The fix is
    algebraic, not a wider accumulator: every truncated row term is
    divisible by ``2^vbl`` (row r < R contributes
    ``((d_r*a - neg_r*kind) >> m_r) * 2^(m_r + 2r)`` with
    ``m_r + 2r == vbl``; row r >= R contributes ``d_r*a*4^r`` with
    ``2r >= vbl``), so the *whole BBM product* is ``2^vbl * M`` and the
    dot form accumulates the scaled ``M = a*bq + sum_r q_r`` directly:

        y = (dot(a, bq) + sum_k sum_{r<R} q_{r,k}) << (vbl - shift)

    (per-product ``>> (shift - vbl)`` inside the sum when shift > vbl).
    Accumulating at scale ``2^-max(vbl, shift)`` bounds the partial sums
    by ``k * 2^(2wl - 1 - max(vbl, shift))`` — never looser than the rows
    envelope ``k * 2^(2wl - 1 - shift)``, so the dot form is int32-safe
    whenever the rows form is, for every vbl.  Returns that bound.
    """
    return k * 2 ** max(2 * wl - 1 - max(vbl, shift), 0)


def amm_chunk_len(wl: int, vbl: int) -> int:
    """Largest K-chunk the contracted dot form accumulates int32-exactly.

    The contracted lowering (``bbm_matmul.bbm_matmul_scaled`` and the
    ``amm_dense`` bitexact mode built on it) sums BBM products at their
    natural ``2^-vbl`` scale through three int32 intermediates, each with
    its own worst-case growth per accumulated product:

      * the scaled total ``M = a*bq + sum_r q_r``:   ``2^(2wl - 1 - vbl)``
        (``dotform_scaled_bound``),
      * the per-row digit contraction ``dot(a, d_r)``:  ``2^wl``
        (``|d| <= 2``, ``|a| <= 2^(wl-1)``),
      * the per-row mod-term contraction:             ``< 2^vbl``
        (each residue is ``< 2^m_r <= 2^vbl``).

    A chunk of this length keeps every one of them strictly inside int32,
    so chunk partials are *exact integers* and any cross-chunk combine
    order gives the same result — the property the oracle-equality tests
    lean on.  Returns at least 1 (``wl = 16, vbl = 0`` degenerates to
    per-product chunks: the exact full-scale product alone fills int32).
    """
    bound = 2 ** 31 - 1
    c = bound >> max(2 * wl - 1 - vbl, 0)
    if num_corr_rows(wl, vbl):
        c = min(c, bound >> (wl + 1), bound >> vbl)
    return max(c, 1)


def f32_exact_chunk_len(wl: int, vbl: int) -> int:
    """Largest K-chunk the dot form contracts *exactly* in float32.

    Same three intermediates as ``amm_chunk_len``, tighter budget: every
    integer of magnitude <= 2^24 is exact in float32, and when the sum of
    |term| over a chunk stays <= 2^24 every partial sum — in *any*
    association order, so tree-reducing matmul units included — is an
    exactly-representable integer and every add is exact.  Chunks of this
    length therefore let the dot form's contractions ride the f32 matmul
    units (measured ~5x the s32 dot throughput on CPU XLA; the native MXU
    lanes on TPU at HIGHEST precision) while remaining bit-identical to
    the int32 contraction.  Unlike ``amm_chunk_len`` this may return 0 —
    operating points whose single product already overflows the budget
    (e.g. wl=16, vbl<=6) have no exact f32 envelope and keep s32 dots.
    """
    bound = 1 << 24
    c = bound >> max(2 * wl - 1 - vbl, 0)
    if num_corr_rows(wl, vbl):
        c = min(c, bound >> (wl + 1), bound >> vbl)
    return c


def resolve_form(form: str | None) -> str:
    """Trace-time accumulate-form selection: "rows" | "dot" | None (auto).

    ``None`` picks the dot form: its re-derived envelope
    (``dotform_scaled_bound``) is never looser than the rows envelope, so
    no operating point needs a *numerical* fallback, and it is the faster
    form wherever the backend has real matmul/vector throughput.  (The
    kernel entry points still route oversized auto-form calls to "rows"
    for *memory* reasons — their windowed / correction temporaries trade
    against the rows form's streaming; see ``_DOT_WINDOW_BUDGET`` /
    ``_DOT_CORR_BUDGET`` at the call sites.)  ``"rows"`` keeps the
    streaming Pallas emulation.
    """
    if form in (None, "dot"):
        return "dot"
    if form == "rows":
        return "rows"
    raise ValueError(f"unknown accumulate form {form!r} "
                     f"(expected 'rows', 'dot' or None)")


def bbm_rows_product(a_s, bu, *, wl: int, vbl: int, kind: int):
    """Broken-Booth product of signed ``a_s`` and unsigned wl-bit ``bu``.

    Raw-code wrapper: decodes ``bu`` then accumulates, for callers whose
    multiplier operand is not constant (or not worth hoisting).  ``a_s``
    and ``bu`` are int32 arrays with broadcast-compatible shapes; the
    result has the broadcast shape.  Bit-identical to
    ``core.bbm.bbm_mul(a, b, wl, vbl, kind)`` for in-range operands.
    """
    mag, neg = booth_precode(bu, wl)
    return bbm_rows_product_precoded(a_s, mag, neg, wl=wl, vbl=vbl,
                                     kind=kind)
