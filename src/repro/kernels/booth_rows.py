"""Shared Broken-Booth row accumulation for the Pallas kernels.

One unrolled, shift-only implementation of the paper's partial-product
truncation, used by both ``bbm_matmul`` and the FIR filterbank kernel so the
Booth row loop is written exactly once on the kernel side.  It mirrors the
closed forms in ``core.bbm`` (``bbm_type0`` / ``bbm_type1``) but avoids
integer division (``floor_divide``) in favour of arithmetic shifts, which is
what the TPU VPU actually supports; ``(x >> m) << m`` is the same
floor-toward ``-inf`` truncation for two's-complement values.

Everything is resolved at trace time: the row loop is unrolled over the
``wl/2`` radix-4 rows and the per-row mask widths are Python ints, so the
helper is safe to call from inside a Pallas kernel body as well as from
plain jitted code.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.booth import num_pp_rows

__all__ = ["bbm_rows_product", "split_signed"]


def split_signed(x, wl: int):
    """(unsigned wl-bit view, signed reinterpretation) of int32 codes."""
    mask = (1 << wl) - 1
    sign = 1 << (wl - 1)
    xu = x & mask
    return xu, jnp.where(xu >= sign, xu - (1 << wl), xu)


def bbm_rows_product(a_s, bu, *, wl: int, vbl: int, kind: int):
    """Broken-Booth product of signed ``a_s`` and unsigned wl-bit ``bu``.

    ``a_s`` and ``bu`` are int32 arrays with broadcast-compatible shapes;
    the result has the broadcast shape.  Bit-identical to
    ``core.bbm.bbm_mul(a, b, wl, vbl, kind)`` for in-range operands.
    ``vbl = 0`` reduces both kinds to the exact Booth product.
    """
    prod = None
    prev_hi = None
    for r in range(num_pp_rows(wl)):
        # booth digit of b for row r: d = -2*b_hi + b_mid + b_lo
        b_hi = (bu >> (2 * r + 1)) & 1
        b_mid = (bu >> (2 * r)) & 1
        b_lo = jnp.zeros_like(b_mid) if r == 0 else prev_hi
        prev_hi = b_hi
        d = -2 * b_hi + b_mid + b_lo
        m = max(0, vbl - 2 * r)           # bits nullified in this row
        if kind == 0:
            rows = d * a_s
            contrib = (rows >> m) << m    # floor for two's complement
        else:
            mag = jnp.abs(d)
            pos = mag * a_s
            rows = jnp.where(b_hi == 1, -pos - 1, pos)
            contrib = (rows >> m) << m
            if m == 0:                    # S dot survives only at m == 0
                contrib = contrib + b_hi
        term = contrib << (2 * r)
        prod = term if prod is None else prod + term
    return prod
