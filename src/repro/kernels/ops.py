"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so every kernel runs (and is tested)
on CPU via the Pallas interpreter; on TPU backends the compiled kernels are
used.  The wrappers also enforce the kernels' documented envelopes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .bbm_matmul import bbm_matmul as _bbm_matmul
from .bbm_matmul import bbm_matmul_precoded as _bbm_matmul_precoded
from .fir_kernel import fir_bbm_bank as _fir_bbm_bank
from .fir_kernel import fir_bbm_bank_precoded as _fir_bbm_bank_precoded
from .flash_attention import flash_attention as _flash_attention
from .quant_matmul import quant_matmul as _quant_matmul

__all__ = ["on_tpu", "bbm_matmul", "bbm_matmul_precoded", "fir_filterbank",
           "fir_filterbank_precoded", "quant_matmul", "flash_attention"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _matmul_envelope(k: int, wl: int, shift: int) -> None:
    # int32 overflow envelope for the *result*: K * max|product >> shift|
    # < 2^31.  This bounds the truncated-and-shifted sum, which is what
    # every form returns.  The dot form accumulates BBM products at their
    # natural 2^-max(vbl, shift) scale (every product is divisible by
    # 2^vbl), so its re-derived envelope — booth_rows.dotform_scaled_bound
    # — is never looser than this one: one check gates both forms.
    if k * (2 ** max(2 * wl - 1 - shift, 0)) >= 2 ** 31:
        raise ValueError(
            f"accumulation may overflow int32: K={k}, wl={wl}, shift={shift};"
            " raise `shift` (fixed-point rescale) or reduce K")


def bbm_matmul(x, w, *, wl: int, vbl: int, kind: int = 0, shift: int = 0,
               interpret=None, form=None, **block_kw):
    """Bit-exact Broken-Booth matmul (int32 codes in/out).

    form: "rows" | "dot" | None (auto) — see ``bbm_matmul_precoded``.
    """
    _matmul_envelope(x.shape[-1], wl, shift)
    if interpret is None:
        interpret = not on_tpu()
    return _bbm_matmul(x, w, wl=wl, vbl=vbl, kind=kind, shift=shift,
                       interpret=interpret, form=form, **block_kw)


def bbm_matmul_precoded(x, wmag, wneg, *, wl: int, vbl: int, kind: int = 0,
                        shift: int = 0, interpret=None, form=None,
                        **block_kw):
    """Broken-Booth matmul on precoded weight-digit planes.

    wmag, wneg: (wl//2, K, N) planes from ``kernels.booth_precode`` —
    decode the constant weight operand once, reuse across calls.
    form: "rows" keeps the VPU row emulation, "dot" puts the dominant
    contraction on the matmul units (None auto-picks "dot").
    """
    _matmul_envelope(x.shape[-1], wl, shift)
    if interpret is None:
        interpret = not on_tpu()
    return _bbm_matmul_precoded(x, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                                shift=shift, interpret=interpret, form=form,
                                **block_kw)


def fir_filterbank(x, h, *, wl: int, vbl: int, kind: int = 0,
                   shift: int = 0, interpret=None, form=None, **block_kw):
    """Batched multi-channel Broken-Booth FIR (int32 codes in/out).

    x: (C, N) signal codes, h: (C, taps) per-channel tap banks (or (taps,)
    shared).  The int32 envelope taps * 2^(2*wl-1-shift) < 2^31 is checked
    inside the kernel wrapper and covers both accumulate forms (the dot
    form's scaled accumulation is never looser —
    ``booth_rows.dotform_scaled_bound``).
    """
    if interpret is None:
        interpret = not on_tpu()
    return _fir_bbm_bank(x, h, wl=wl, vbl=vbl, kind=kind, shift=shift,
                         interpret=interpret, form=form, **block_kw)


def fir_filterbank_precoded(x, hmag, hneg, *, wl: int, vbl: int,
                            kind: int = 0, shift: int = 0, interpret=None,
                            form=None, **block_kw):
    """Filterbank on precoded tap-digit planes (int32 codes in/out).

    x: (C, N) signal codes; hmag, hneg: (wl//2, C, taps) digit planes from
    ``kernels.booth_precode`` of the tap bank — decode once per bank, reuse
    across every flush that shares it.
    form: "rows" | "dot" | None (auto) — see ``fir_bbm_bank_precoded``.
    """
    if interpret is None:
        interpret = not on_tpu()
    return _fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                  shift=shift, interpret=interpret,
                                  form=form, **block_kw)


def quant_matmul(x, w, s_x, s_w, mu=0.0, sigma=0.0, *, wl: int = 16,
                 seed=0, interpret=None, **block_kw):
    """Fused quantized matmul with calibrated noise injection.

    s_x, s_w and seed may be python numbers or traced scalars (they enter
    the kernel as operands); mu and sigma are static python floats.
    """
    if interpret is None:
        interpret = not on_tpu()
    return _quant_matmul(x, w, s_x, s_w, float(mu), float(sigma), wl=wl,
                         seed=seed, interpret=interpret, **block_kw)


def flash_attention(q, k, v, *, causal: bool = True, interpret=None,
                    **block_kw):
    """Blockwise online-softmax attention."""
    if interpret is None:
        interpret = not on_tpu()
    return _flash_attention(q, k, v, causal=causal, interpret=interpret,
                            **block_kw)
