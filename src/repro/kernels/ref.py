"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.bbm import bbm_type0, bbm_type1

__all__ = ["bbm_matmul_ref", "fir_bank_ref", "quant_matmul_ref",
           "attention_ref"]


def bbm_matmul_ref(x, w, *, wl: int, vbl: int, kind: int = 0,
                   shift: int = 0):
    """out[m,n] = sum_k (bbm(x[m,k], w[k,n]) >> shift), int32 accumulation."""
    fn = bbm_type0 if kind == 0 else bbm_type1
    prod = fn(x[:, :, None], w[None, :, :], wl, vbl)     # (M, K, N)
    if shift:
        prod = prod >> shift
    return jnp.sum(prod, axis=1, dtype=jnp.int32)


def fir_bank_ref(x, w, *, wl: int, vbl: int, kind: int = 0, shift: int = 0):
    """y[c,n] = sum_k (bbm(x[c,n-k], h[c,k]) >> shift), zero initial state.

    x: (C, N) codes, h: (C, taps) codes; the pure-jnp oracle for the
    filterbank kernel, built on the closed forms in ``core.bbm``.
    """
    h = w
    fn = bbm_type0 if kind == 0 else bbm_type1
    channels, n = x.shape
    taps = h.shape[1]
    xp = jnp.pad(x, ((0, 0), (taps - 1, 0)))
    # w[c, n, k] = x[c, n - k] (zeros before the signal starts)
    idx = jnp.arange(n)[:, None] + (taps - 1) - jnp.arange(taps)[None, :]
    win = xp[:, idx]                                      # (C, N, taps)
    prod = fn(win, h[:, None, :], wl, vbl)
    if shift:
        prod = prod >> shift
    return jnp.sum(prod, axis=-1, dtype=jnp.int32)


def quant_matmul_ref(x, w, s_x, s_w, mu, sigma, *, wl: int = 16, key=None):
    """Quantize->exact matmul->noise->dequantize, noise via jax.random.

    The kernel uses its own in-tile counter hash, so elementwise equality
    with this oracle only holds for mu = sigma = 0; with noise the tests
    compare *moments* (see tests/test_kernels.py).
    """
    lim = float(2 ** (wl - 1))
    xq = jnp.clip(jnp.round(x / s_x), -lim, lim - 1)
    wq = jnp.clip(jnp.round(w / s_w), -lim, lim - 1)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    k_total = x.shape[-1]
    if key is not None and (mu != 0.0 or sigma != 0.0):
        z = jax.random.normal(key, acc.shape, jnp.float32)
        acc = acc + mu * k_total + sigma * (k_total ** 0.5) * z
    return acc * (s_x * s_w)


def attention_ref(q, k, v, *, causal: bool = True):
    """Naive softmax attention, fp32 internals.  q,k,v: (B, H, S, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
