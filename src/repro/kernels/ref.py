"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.bbm import bbm_type0, bbm_type1
from ..core.faults import apply_acc_fault
from ..core.multipliers import MulSpec, mul as core_mul
from .booth_rows import amm_chunk_len

__all__ = ["amm_approx_ref", "amm_attention_ref", "amm_coded_ref",
           "amm_coded_kblocks_ref", "amm_decode_attention_codes_ref",
           "amm_decode_attention_ref", "amm_dense_ref", "amm_dot_ref",
           "amm_faulty_ref", "amm_flash_attention_ref", "amm_quantize",
           "bbm_matmul_ref", "fir_bank_ref", "quant_matmul_ref",
           "attention_ref"]

# Booth-family specs and their closed-form truncation kind; every other
# multiplier family has no dot-form lowering and keeps the scalar path
AMM_BOOTH_KINDS = {"booth": 0, "bbm0": 0, "bbm1": 1}


def amm_effective_vbl(spec: MulSpec) -> int:
    """VBL the accumulation scale is derived from (exact booth: 0)."""
    return 0 if spec.name == "booth" else spec.param


def amm_quantize(v, wl: int):
    """(int32 codes, f32 dynamic scale) — THE amm bitexact quantizer.

    One definition on purpose: the datapath (``models.common``), the
    per-parameter plane cache (``AmmRuntime.precode``) and this module's
    oracle must quantize *bit-for-bit* identically or the suite's
    ``assert_array_equal`` contract silently degrades to luck.  Codes are
    ``clip(round(v / s), -lim-1, lim)`` with ``s = max|v| / lim`` floored
    at 1e-12 (the symmetric dynamic-range grid; the most-negative code is
    reachable only by the clip bound).

    The arithmetic runs in float32 regardless of v's dtype.  This is
    load-bearing, not cosmetic: LM activations arrive as bf16, where the
    wl = 16 clip bound 32767 is *unrepresentable* (nearest bf16 is
    32768) — quantizing in the input dtype emits code +32768, which the
    Booth decode masks to the wl-bit field and reinterprets as -32768, a
    sign flip of the largest activation that the shared-quantizer oracle
    equality can never see — and bf16's 8-bit mantissa would coarsen the
    code grid itself.
    """
    lim = 2 ** (wl - 1) - 1
    vf = jnp.asarray(v, jnp.float32)
    # multiply by the reciprocal constant rather than divide by lim: XLA's
    # algebraic simplifier rewrites division-by-constant exactly this way
    # inside compiled programs (1 ULP below the correctly-rounded quotient),
    # while eager execution divides for real — writing the multiply makes
    # the scale bit-identical across compilation contexts, which the
    # flash-amm vs chunked-amm equality contract depends on (their
    # quantizers run in different contexts by design).  Division by the
    # *runtime* scale below is a true fdiv in every context.
    s = jnp.maximum(jnp.max(jnp.abs(vf)) * (1.0 / lim), 1e-12)
    s = jax.lax.stop_gradient(s)
    codes = jnp.clip(jnp.round(vf / s), -lim - 1, lim).astype(jnp.int32)
    return codes, s


def amm_approx_ref(x, w, spec: MulSpec):
    """Scalar outer-product oracle of ``amm_dense`` mode="bitexact".

    The retained reference datapath: dynamic-range quantize both operands
    to wl-bit codes, form every scalar product through the closed forms in
    ``core.multipliers`` (materializing the full (..., K, N) product
    grid — which is exactly why this is the *oracle*, not the datapath),
    reduce, and descale.  For Booth-family specs the reduction mirrors the
    dot form's contract bit for bit: products are divided by ``2^vbl``
    (every BBM product is divisible — see booth_rows), summed int32-exact
    per K-chunk of ``amm_chunk_len``, and the chunk partials are combined
    in float32 in chunk order, so oracle and dot form compute identical
    floats whenever both are in contract.  Non-Booth families (bam,
    kulkarni, etm) keep the historical float32 product sum.

    x: (..., K) float, w: (K, N) float; returns the approximate forward
    value (no straight-through composition — ``amm_dense_ref`` adds it).
    """
    wl = spec.wl
    xq, s_x = amm_quantize(x, wl)
    wq, s_w = amm_quantize(w, wl)
    prod = core_mul(spec)(xq[..., :, None], wq[None, :, :])  # (..., K, N)
    if spec.name in AMM_BOOTH_KINDS:
        vbl = amm_effective_vbl(spec)
        scaled = prod >> vbl                  # exact: divisible by 2^vbl
        k = x.shape[-1]
        chunk = amm_chunk_len(wl, vbl)
        if k <= chunk:
            yq = jnp.sum(scaled, axis=-2, dtype=jnp.int32
                         ).astype(jnp.float32) * float(1 << vbl)
        else:
            yq = jnp.zeros(scaled.shape[:-2] + scaled.shape[-1:],
                           jnp.float32)
            for lo in range(0, k, chunk):     # chunk order == the scan's
                part = jnp.sum(scaled[..., lo:lo + chunk, :], axis=-2,
                               dtype=jnp.int32)
                yq = yq + part.astype(jnp.float32)
            yq = yq * float(1 << vbl)
    else:
        yq = jnp.sum(prod.astype(jnp.float32), axis=-2)
    return (yq * (s_x * s_w)).astype(x.dtype)


def _coded_yq_ref(aq, b_codes, spec: MulSpec):
    """Chunk-scheduled closed-form contraction of two code grids.

    The shared core of the codes-in oracles: products through
    ``core.multipliers``, divided by ``2^vbl`` (exact), summed int32 per
    K-chunk of ``amm_chunk_len``, chunk partials combined in float32 in
    chunk order, rescaled — the Booth branch of ``amm_approx_ref`` minus
    its quantization and descale.  Returns the full-product-scale float
    accumulator ``yq``.
    """
    prod = core_mul(spec)(aq[..., :, None], b_codes[None, :, :])
    vbl = amm_effective_vbl(spec)
    scaled = prod >> vbl
    k = aq.shape[-1]
    chunk = amm_chunk_len(spec.wl, vbl)
    if k <= chunk:
        return jnp.sum(scaled, axis=-2, dtype=jnp.int32
                       ).astype(jnp.float32) * float(1 << vbl)
    yq = jnp.zeros(scaled.shape[:-2] + scaled.shape[-1:], jnp.float32)
    for lo in range(0, k, chunk):             # chunk order == the scan's
        part = jnp.sum(scaled[..., lo:lo + chunk, :], axis=-2,
                       dtype=jnp.int32)
        yq = yq + part.astype(jnp.float32)
    return yq * float(1 << vbl)


def amm_coded_ref(a, b_codes, s_b, spec: MulSpec):
    """Scalar oracle of ``bbm_matmul.bbm_matmul_coded``.

    ``a`` (M, K) float is quantized per call (shared ``amm_quantize``);
    ``b_codes`` (K, N) arrive pre-quantized with scalar or per-column
    ``s_b`` — same contraction schedule and descale expression as the
    codes-in datapath, products through the closed forms.
    """
    if spec.name not in AMM_BOOTH_KINDS:
        raise ValueError(f"no codes-in lowering for family {spec.name!r}")
    aq, s_a = amm_quantize(a, spec.wl)
    yq = _coded_yq_ref(aq, jnp.asarray(b_codes, jnp.int32), spec)
    s_b = jnp.asarray(s_b, jnp.float32)
    if s_b.ndim == 1:
        s_b = s_b[None, :]
    return (yq * (s_a * s_b)).astype(a.dtype)


def amm_coded_kblocks_ref(a, b_codes, s_b, spec: MulSpec, *, block: int):
    """Scalar oracle of ``bbm_matmul.bbm_matmul_coded_kblocks``.

    Per-K-block descale in block order: each block's closed-form
    contraction (itself chunk-scheduled when ``block`` exceeds
    ``amm_chunk_len``) is scaled by ``s_a * s_b[j]`` and combined in
    float32 — the same float expression tree as the datapath.
    """
    if spec.name not in AMM_BOOTH_KINDS:
        raise ValueError(f"no codes-in lowering for family {spec.name!r}")
    kk = b_codes.shape[0]
    if kk % block:
        raise ValueError(f"K={kk} not a multiple of block={block}")
    aq, s_a = amm_quantize(a, spec.wl)
    b_codes = jnp.asarray(b_codes, jnp.int32)
    acc = None
    for bi, lo in enumerate(range(0, kk, block)):
        yq = _coded_yq_ref(aq[..., lo:lo + block], b_codes[lo:lo + block],
                           spec)
        part = yq * (s_a * s_b[bi])
        acc = part if acc is None else acc + part
    return acc.astype(a.dtype)


def amm_faulty_ref(x, w, spec: MulSpec, fault=None):
    """Scalar oracle of the *fault-injected* dot-form datapath.

    Mirrors ``bbm_matmul_dynamic(..., fault=)`` product for product:
    quantize both operands (shared ``amm_quantize``), Booth-decode the
    multiplier operand and fault its digit planes
    (``booth_rows.booth_precode_faulty`` — the keyed masks depend only on
    the ``FaultSpec`` and the (wl//2, K, N) plane shape, so the datapath
    faults the same cells), form every scalar product through the
    per-element precoded closed form (the (..., K, N) grid that makes
    this the oracle), divide by ``2^vbl`` (still exact: the per-row
    divisibility argument is digit-value-agnostic, so it survives any
    fault that stays in the decode domain), sum int32-exact per K-chunk
    with the *same* per-chunk accumulator upsets
    (``core.faults.apply_acc_fault``, folded by the same chunk index),
    combine in float32 in chunk order, rescale, descale.  Booth-family
    specs only (the fault model lives in the Booth decode).  A disabled
    ``fault`` reduces to the Booth branch of ``amm_approx_ref``
    bit-for-bit.

    x: (M, K) float, w: (K, N) float — 2-D on purpose: the keyed "acc"
    masks are drawn at the (M, N) partial shape, which is the datapath's
    shape only when leading axes are unbatched (vmap callers quantize
    per slice anyway).
    """
    from .booth_rows import bbm_rows_product_precoded, booth_precode_faulty, \
        split_signed
    if spec.name not in AMM_BOOTH_KINDS:
        raise ValueError(f"fault injection needs a Booth-family spec, "
                         f"not {spec.name!r}")
    wl = spec.wl
    vbl = amm_effective_vbl(spec)
    kind = AMM_BOOTH_KINDS[spec.name]
    xq, s_x = amm_quantize(x, wl)
    wq, s_w = amm_quantize(w, wl)
    mag, neg = booth_precode_faulty(wq, wl, fault, vbl=vbl)
    _, x_s = split_signed(xq, wl)
    prod = bbm_rows_product_precoded(
        x_s[..., :, None], mag, neg, wl=wl, vbl=vbl, kind=kind)  # (M, K, N)
    scaled = prod >> vbl                      # exact: divisible by 2^vbl
    k = x.shape[-1]
    chunk = amm_chunk_len(wl, vbl)
    yq = jnp.zeros(scaled.shape[:-2] + scaled.shape[-1:], jnp.float32)
    for ci, lo in enumerate(range(0, k, chunk)):  # chunk order == the scan's
        part = jnp.sum(scaled[..., lo:lo + chunk, :], axis=-2,
                       dtype=jnp.int32)
        part = apply_acc_fault(part, fault, ci)
        yq = yq + part.astype(jnp.float32)
    yq = yq * float(1 << vbl)
    return (yq * (s_x * s_w)).astype(x.dtype)


def amm_dense_ref(x, w, spec: MulSpec):
    """Full ``amm_dense`` bitexact oracle including the STE composition.

    Returns ``exact + (approx - exact)`` — the same float expression the
    layer wraps in ``stop_gradient`` — so the comparison against
    ``amm_dense`` is bitwise, not just value-of-approx.
    """
    exact = x @ w
    return exact + (amm_approx_ref(x, w, spec) - exact)


def amm_dot_ref(a, b, spec: MulSpec):
    """Scalar oracle of ``bbm_matmul.bbm_matmul_dynamic``, batched.

    The both-operands-dynamic product (attention scores/PV) has no weight
    side, so its oracle is ``amm_approx_ref`` — which already quantizes
    *both* operands per call — vmapped over the shared leading batch axes:
    each (M, K) x (K, N) slice gets its own pair of dynamic scales,
    exactly the granularity the dot-form datapath derives under the same
    vmap.  a: (..., M, K), b: (..., K, N) with matching leading axes.
    """
    if a.ndim != b.ndim:
        raise ValueError(f"operand ranks differ: {a.shape} vs {b.shape}")
    fn = lambda aa, bb: amm_approx_ref(aa, bb, spec)
    for _ in range(a.ndim - 2):
        fn = jax.vmap(fn)
    return fn(a, b)


def amm_attention_ref(q, k, v, spec: MulSpec, *, causal: bool = True,
                      q_offset=0, bq: int = 512, bk: int = 1024,
                      kv_len=None):
    """Scalar attention oracle for the approximate-attention datapath.

    Runs the *same* chunked online-softmax schedule as
    ``models.attention.chunked_attention`` — blocking, masking, max/
    denominator renormalization, float op order — with every score and
    value product formed through the scalar closed forms
    (``amm_dot_ref`` -> ``core.multipliers``) instead of the dot-form
    contraction.  Sharing the schedule is deliberate and mirrors the
    ``amm_dense_ref`` contract: the multiplier *datapath* is what is
    oracled, and one source of truth for the schedule is what makes
    dot-vs-oracle equality ``assert_array_equal`` instead of allclose.

    q: (B, Sq, H, D), k/v: (B, Skv, KV, D); same signature semantics as
    ``chunked_attention``.  Lazy import: models sits above kernels in the
    layering, so the oracle pulls the schedule in at call time.
    """
    from ..models.attention import chunked_attention
    rt = _attn_runtime(spec)
    return chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                             bq=bq, bk=bk, kv_len=kv_len, amm=rt,
                             amm_oracle=True)


def amm_flash_attention_ref(q, k, v, spec: MulSpec, *, causal: bool = True):
    """Scalar oracle of ``flash_attention.flash_attention_amm``.

    The flash-amm kernel is bit-identical to the chunked schedule at the
    flash tile sizes (quantization is per block, so the blocking is part
    of the contract); its oracle is therefore ``amm_attention_ref`` — the
    same schedule with every product through the scalar closed forms —
    pinned to ``FLASH_AMM_BQ``/``FLASH_AMM_BK`` and transposed to the
    kernel's (B, H, S, D) layout.  Head counts must be matched (the
    caller repeats KV heads, as for the kernel).
    """
    from .flash_attention import FLASH_AMM_BK, FLASH_AMM_BQ
    out = amm_attention_ref(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), spec, causal=causal,
                            bq=FLASH_AMM_BQ, bk=FLASH_AMM_BK)
    return out.transpose(0, 2, 1, 3)


def amm_decode_attention_ref(q, k_cache, v_cache, kv_len, spec: MulSpec, *,
                             ste: bool = True):
    """Scalar oracle of single-position amm attention against a cache.

    Mirrors ``models.attention.decode_attention`` the same way
    ``amm_attention_ref`` mirrors the chunked path: shared schedule,
    scalar closed-form products.  ``ste=False`` drops the straight-through
    composition (pure approximate forward) — the value the code-domain
    decode path computes, which never forms an exact product.
    """
    from ..models.attention import decode_attention
    rt = _attn_runtime(spec)
    return decode_attention(q, k_cache, v_cache, kv_len, amm=rt,
                            amm_oracle=True, amm_ste=ste)


def amm_decode_attention_codes_ref(q, cache, kv_len, spec: MulSpec):
    """Scalar oracle of ``models.attention.decode_attention_codes``.

    Shared schedule (the code-domain decode itself, oracle mode), scalar
    closed-form products via ``amm_coded_ref``/``amm_coded_kblocks_ref``.
    ``cache`` is a per-layer slice of the int-code KV cache
    (``serve.kv_cache.init_code_cache`` leaves without the layer axis).
    """
    from ..models.attention import decode_attention_codes
    rt = _attn_runtime(spec)
    return decode_attention_codes(q, cache, kv_len, amm=rt,
                                  amm_oracle=True)


def _attn_runtime(spec: MulSpec):
    """AmmRuntime carrying ``spec`` with attention routing enabled."""
    from ..configs.base import AmmConfig
    from ..models.common import AmmRuntime
    if spec.name not in AMM_BOOTH_KINDS:
        raise ValueError(f"no attention lowering for family {spec.name!r}")
    return AmmRuntime(AmmConfig(mode="bitexact", mul=spec.name, wl=spec.wl,
                                param=spec.param, apply_to="all"))


def bbm_matmul_ref(x, w, *, wl: int, vbl: int, kind: int = 0,
                   shift: int = 0):
    """out[m,n] = sum_k (bbm(x[m,k], w[k,n]) >> shift), int32 accumulation."""
    fn = bbm_type0 if kind == 0 else bbm_type1
    prod = fn(x[:, :, None], w[None, :, :], wl, vbl)     # (M, K, N)
    if shift:
        prod = prod >> shift
    return jnp.sum(prod, axis=1, dtype=jnp.int32)


def fir_bank_ref(x, w, *, wl: int, vbl: int, kind: int = 0, shift: int = 0):
    """y[c,n] = sum_k (bbm(x[c,n-k], h[c,k]) >> shift), zero initial state.

    x: (C, N) codes, h: (C, taps) codes; the pure-jnp oracle for the
    filterbank kernel, built on the closed forms in ``core.bbm``.
    """
    h = w
    fn = bbm_type0 if kind == 0 else bbm_type1
    channels, n = x.shape
    taps = h.shape[1]
    xp = jnp.pad(x, ((0, 0), (taps - 1, 0)))
    # w[c, n, k] = x[c, n - k] (zeros before the signal starts)
    idx = jnp.arange(n)[:, None] + (taps - 1) - jnp.arange(taps)[None, :]
    win = xp[:, idx]                                      # (C, N, taps)
    prod = fn(win, h[:, None, :], wl, vbl)
    if shift:
        prod = prod >> shift
    return jnp.sum(prod, axis=-1, dtype=jnp.int32)


def quant_matmul_ref(x, w, s_x, s_w, mu, sigma, *, wl: int = 16, key=None):
    """Quantize->exact matmul->noise->dequantize, noise via jax.random.

    The kernel uses its own in-tile counter hash, so elementwise equality
    with this oracle only holds for mu = sigma = 0; with noise the tests
    compare *moments* (see tests/test_kernels.py).  Scales are cast to f32
    up front — they reach the kernel as f32 operands, and the descale
    product ``s_x * s_w`` must round the same way here.
    """
    lim = float(2 ** (wl - 1))
    s_x = jnp.asarray(s_x, jnp.float32)
    s_w = jnp.asarray(s_w, jnp.float32)
    xq = jnp.clip(jnp.round(x / s_x), -lim, lim - 1)
    wq = jnp.clip(jnp.round(w / s_w), -lim, lim - 1)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    k_total = x.shape[-1]
    if key is not None and (mu != 0.0 or sigma != 0.0):
        z = jax.random.normal(key, acc.shape, jnp.float32)
        acc = acc + mu * k_total + sigma * (k_total ** 0.5) * z
    return acc * (s_x * s_w)


def attention_ref(q, k, v, *, causal: bool = True):
    """Naive softmax attention, fp32 internals.  q,k,v: (B, H, S, D)."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
