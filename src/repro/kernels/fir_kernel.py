"""Pallas TPU kernel: direct-form FIR filter with Broken-Booth tap products.

The paper's own workload as a TPU kernel: ``y[n] = sum_k bbm(x[n-k], h[k])``
with the closed-form Broken-Booth product per tap.  The signal is blocked
along time; each block loads its samples plus ``taps-1`` history samples
(halo) into VMEM, and the tap loop is unrolled at trace time (30 taps).

Accumulation is int32; the caller provides wl-bit codes, so the documented
envelope is taps * 2^(2*wl-1) < 2^31 (fine for the paper's 31 taps at
wl <= 12; at wl=16 use the per-product ``shift`` rescale like bbm_matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.booth import num_pp_rows

__all__ = ["fir_bbm"]


def _fir_kernel(x_ref, h_ref, o_ref, *, wl: int, vbl: int, kind: int,
                taps: int, shift: int, block: int):
    i = pl.program_id(0)
    # the whole (padded) signal sits in VMEM (FIR signals are small); each
    # block slices its window + taps-1 halo — overlapping halo reads are not
    # expressible through BlockSpec index maps
    xs = jax.lax.dynamic_slice(x_ref[...], (i * block,),
                               (block + taps - 1,))
    h = h_ref[...]                         # (taps,) int32 codes
    mask = (1 << wl) - 1
    sign = 1 << (wl - 1)

    acc = jnp.zeros((block,), jnp.int32)
    for t in range(taps):
        # window of samples feeding tap t for each output in the block
        a = jax.lax.dynamic_slice(xs, (taps - 1 - t,), (block,))
        au = a & mask
        a_s = jnp.where(au >= sign, au - (1 << wl), au)
        bu = h[t] & mask
        prod = jnp.zeros((block,), jnp.int32)
        prev_hi = jnp.int32(0)
        for r in range(num_pp_rows(wl)):
            b_hi = (bu >> (2 * r + 1)) & 1
            b_mid = (bu >> (2 * r)) & 1
            b_lo = jnp.int32(0) if r == 0 else prev_hi
            prev_hi = b_hi
            d = -2 * b_hi + b_mid + b_lo
            m = max(0, vbl - 2 * r)
            if kind == 0:
                rows = d * a_s
                contrib = (rows >> m) << m
            else:
                mag = jnp.abs(d)
                pos = mag * a_s
                rows = jnp.where(b_hi == 1, -pos - 1, pos)
                contrib = (rows >> m) << m
                if m == 0:
                    contrib = contrib + b_hi
            prod = prod + (contrib << (2 * r))
        if shift:
            prod = prod >> shift
        acc = acc + prod
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "shift",
                                             "block", "interpret"))
def fir_bbm(x, h, *, wl: int, vbl: int, kind: int = 0, shift: int = 0,
            block: int = 512, interpret: bool = False):
    """Bit-exact Broken-Booth FIR.  x: (N,) codes, h: (taps,) codes."""
    n = x.shape[0]
    taps = h.shape[0]
    if taps * (2 ** max(2 * wl - 1 - shift, 0)) >= 2 ** 31:
        raise ValueError("accumulator may overflow int32: raise `shift`")
    block = min(block, n)
    nb = pl.cdiv(n, block)
    pad = nb * block - n
    xp = jnp.pad(x, (taps - 1, pad))        # history halo + tail pad
    kernel = functools.partial(_fir_kernel, wl=wl, vbl=vbl, kind=kind,
                               taps=taps, shift=shift, block=block)
    n_pad = xp.shape[0]
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n_pad,), lambda i: (0,)),
            pl.BlockSpec((taps,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb * block,), jnp.int32),
        interpret=interpret,
    )(xp, h)
    return out[:n]
