"""Pallas TPU kernel: multi-channel direct-form FIR filterbank with
Broken-Booth tap products, precoded-digit datapath.

The paper's own workload as a TPU kernel, scaled out: ``C`` independent
channels, each with its own wl-bit tap bank, computed as

    y[c, n] = sum_k shift(bbm(x[c, n-k], h[c, k]))

with the closed-form Broken-Booth product per tap (Type0/Type1) and an
optional per-product arithmetic right shift (the fixed-point MAC rescale
that keeps the int32 accumulator inside its envelope at wl = 16).

Precoded datapath (the perf story of this kernel): the tap bank is the
Booth *multiplier* operand and it is constant across the whole grid, so
its radix-4 digits are decoded exactly once per call — outside the kernel
— by ``booth_rows.booth_precode`` and streamed in as two digit planes of
shape ``(wl//2, C, taps)``, BlockSpec-tiled like the bank itself.  The
kernel body (``bbm_rows_product_precoded``) is then multiply-free:
each Booth row is a select among ``{0, a_s, a_s << 1}`` plus a negate,
instead of re-deriving digits from the raw code inside every tap of every
``(channels, time)`` grid step.  ``fir_bbm_bank`` keeps the raw-code
signature and precodes internally; ``fir_bbm_bank_precoded`` accepts
already-decoded planes so callers with long-lived banks (serving, the
sharded filterbank) pay the decode once per bank lifetime.

Streaming layout:

  * 2-D grid over (channel blocks, time blocks); BlockSpec tiles of shape
    ``(bc, bt)`` stream through VMEM, so signal length is bounded by HBM,
    not VMEM.
  * The ``taps - 1`` history samples each time block needs from its left
    neighbour are carried through a VMEM scratch buffer: the time axis is
    sequential ("arbitrary" dimension semantics), each step deposits its
    last ``taps - 1`` raw codes into the scratch and the next step reads
    them back — an explicit halo exchange instead of overlapped loads,
    which BlockSpec index maps cannot express.  At ``t == 0`` the halo is
    zeroed (zero initial filter state, matching the host reference).
  * The channel grid axis is "parallel": a megacore split along channels
    keeps its own scratch, and every channel block re-zeroes the halo at
    its first time step, so the carry never crosses channel blocks.

Overflow envelope: taps * 2^(2*wl - 1 - shift) < 2^31 (checked on entry;
at the paper's operating point of 31 taps x wl = 16 this requires
``shift >= 5`` — see ``min_safe_shift``).

Dot form (``form="dot"``): the tap loop collapses into one dense integer
contraction.  ``bbm(a, h) == a*h - correction(a mod 2^vbl, digits)``
(see ``booth_rows``), and since the correction's own linear term is a
contraction too, every product is ``2^vbl * M`` and

    y[c, n] = ( dot(x, bq)[c, n] + Q[c, n] ) << (vbl - shift)

where the dominant term contracts the *full* signal against the
truncation-surviving digit value ``bq`` — a windowed ``lax.dot_general``
(the MXU path) on accelerator backends, a fused multiply-accumulate over
(C, N) slices on CPU — and only the ``ceil(vbl/2)`` truncated rows walk
the digit planes (``Q``).  The scaled accumulation keeps the dot form
inside the rows-form int32 envelope for every vbl
(``booth_rows.dotform_scaled_bound`` carries the re-derived analysis).
The dot form is plain jitted XLA (no ``pallas_call``): handing the
contraction to XLA is the whole point, and it is what reaches the matmul
units on every backend.  ``form=None`` auto-picks it; every form is
bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.booth import num_pp_rows
from .booth_rows import (bbm_rows_product_precoded, booth_high_value,
                         booth_precode, resolve_form, scaled_trunc_rows,
                         split_signed)

__all__ = ["fir_bbm", "fir_bbm_bank", "fir_bbm_bank_precoded",
           "min_safe_shift"]

# auto-form only: above this many int32 elements the windowed dot operand
# (C, N, taps) stops being a fair trade against the streaming rows kernel
# on accelerator backends, so form=None falls back to streaming there.  An
# explicit form="dot" is honored regardless — the caller owns the memory
# then.  (The CPU dot branch is per-tap over (C, N) slices and never
# materializes the window, so no gate applies.)
_DOT_WINDOW_BUDGET = 1 << 26


def min_safe_shift(taps: int, wl: int) -> int:
    """Smallest per-product shift keeping the int32 accumulator safe."""
    shift = 0
    while taps * (2 ** max(2 * wl - 1 - shift, 0)) >= 2 ** 31:
        shift += 1
    return shift


def _check_envelope(taps: int, wl: int, shift: int) -> None:
    if taps * (2 ** max(2 * wl - 1 - shift, 0)) >= 2 ** 31:
        raise ValueError(
            f"accumulator may overflow int32: taps={taps}, wl={wl}, "
            f"shift={shift}; raise `shift` to >= {min_safe_shift(taps, wl)}")


def _fir_bank_kernel(x_ref, hm_ref, hs_ref, o_ref, halo_ref, *, wl: int,
                     vbl: int, kind: int, taps: int, shift: int, bt: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _zero_state():
        # zero initial filter state at the start of every channel block's
        # time sweep (also isolates channel blocks from one another)
        halo_ref[...] = jnp.zeros_like(halo_ref)

    # halo exchange: taps-1 raw codes deposited by the previous time block
    xs = jnp.concatenate([halo_ref[...], x_ref[...]], axis=1)
    _, xs_s = split_signed(xs, wl)          # sign-extend once per block
    hm = hm_ref[...]                        # (wl//2, bc, taps) digit planes
    hs = hs_ref[...]

    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for k in range(taps):
        # window of samples feeding tap k for each output in the block
        a_s = xs_s[:, taps - 1 - k:taps - 1 - k + bt]
        prod = bbm_rows_product_precoded(
            a_s, hm[:, :, k, None], hs[:, :, k, None],
            wl=wl, vbl=vbl, kind=kind)
        if shift:
            prod = prod >> shift
        acc = acc + prod
    o_ref[...] = acc
    halo_ref[...] = xs[:, bt:]              # carry history to the next block


def _fir_bank_dotform(x, hmag, hneg, *, wl: int, vbl: int, kind: int,
                      shift: int, windowed: bool | None = None):
    """Dot-form filterbank: exact contraction + scaled truncated rows.

    Bit-identical to the rows kernel.  Every BBM product is ``2^vbl * M``
    with ``M = a*bq + sum_{r<R} ((d_r*a - neg_r*kind) >> m_r)`` — the
    exact-dot-minus-correction identity with the correction's own linear
    term ``dot(a mod 2^vbl, h)`` folded into the contraction (see
    ``booth_rows.dotform_scaled_bound``) — so the tap loop contracts the
    *full* signal against the truncation-surviving digit value ``bq`` and
    only the ``R = ceil(vbl/2)`` truncated rows walk the digit planes.
    Accumulating at the ``2^-max(vbl, shift)`` scale keeps every partial
    sum inside the rows-form int32 envelope.

    On accelerator backends the contraction is a windowed
    ``lax.dot_general`` over an im2col stack — the matmul-unit (MXU)
    path.  On CPU the same contraction runs as a fused per-tap
    multiply-accumulate over (C, N) slices: XLA CPU has no separate
    matmul unit, and the im2col materialization costs more than it buys.
    Both are trace-time choices of the same arithmetic; ``windowed``
    overrides the backend default (mirroring the rows form's
    ``multiply_free`` knob) so either branch is testable on any backend.
    A ``shift > vbl`` residual forces the per-tap branch — its floor
    applies per product, which the summed window cannot express.
    """
    n = x.shape[1]
    taps = hmag.shape[2]
    _, x_s = split_signed(x, wl)
    bq = booth_high_value(hmag, hneg, wl=wl, vbl=vbl)        # (C, taps)
    # zero codes before the signal starts: the delay line's initial
    # state, same as the rows kernel's zeroed halo
    xp = jnp.pad(x_s, ((0, 0), (taps - 1, 0)))
    u = max(shift - vbl, 0)       # per-product residual rescale (rare)
    if windowed is None:
        windowed = jax.default_backend() != "cpu"
    if windowed and u == 0:
        win = jnp.stack([xp[:, taps - 1 - k: taps - 1 - k + n]
                         for k in range(taps)], axis=-1)     # (C, N, taps)
        dn = (((2,), (1,)), ((0,), (0,)))
        acc = jax.lax.dot_general(win, bq, dn,
                                  preferred_element_type=jnp.int32)
        q = scaled_trunc_rows(win, hmag[:, :, None, :], hneg[:, :, None, :],
                              wl=wl, vbl=vbl, kind=kind)
        if q is not None:
            acc = acc + jnp.sum(q, axis=-1, dtype=jnp.int32)
    else:
        acc = jnp.zeros_like(x_s)
        for k in range(taps):
            a = xp[:, taps - 1 - k: taps - 1 - k + n]
            m_k = a * bq[:, k:k + 1]
            q = scaled_trunc_rows(a, hmag[:, :, k, None], hneg[:, :, k, None],
                                  wl=wl, vbl=vbl, kind=kind)
            if q is not None:
                m_k = m_k + q
            if u:
                m_k = m_k >> u        # shift > vbl: floor per product
            acc = acc + m_k
    if vbl > shift:
        acc = acc << (vbl - shift)
    return acc


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "shift",
                                             "bc", "bt", "interpret",
                                             "form", "windowed"))
def fir_bbm_bank_precoded(x, hmag, hneg, *, wl: int, vbl: int, kind: int = 0,
                          shift: int = 0, bc: int = 8, bt: int = 512,
                          interpret: bool = False,
                          form: str | None = None,
                          windowed: bool | None = None):
    """Broken-Booth FIR filterbank on precoded tap-digit planes.

    x: (C, N) int32 wl-bit signal codes, one row per channel.
    hmag, hneg: (wl//2, C, taps) int32 digit planes from
        ``booth_precode`` of the (C, taps) tap bank — decoded once per
        bank, reused across every call that shares the bank.
    form: "rows" (the streaming Pallas kernel), "dot" (exact contraction
        + scaled truncated rows, on the matmul units) or None (auto: the
        dot form — its envelope is never narrower — except when the
        windowed operand would exceed the streaming budget on accelerator
        backends).  Bit-identical either way; ``bc``/``bt``/``interpret``
        only shape the rows form and ``windowed`` (the dot form's
        im2col-vs-per-tap contraction layout) only the dot form.
    Returns (C, N) int32 accumulator values (sum of shifted products).
    """
    channels, n = x.shape
    n_rows, hc, taps = hmag.shape
    if hmag.shape != hneg.shape:
        raise ValueError(f"mag/neg plane shapes differ: "
                         f"{hmag.shape} vs {hneg.shape}")
    if n_rows != num_pp_rows(wl) or hc != channels:
        raise ValueError(f"digit planes {hmag.shape} do not match "
                         f"wl={wl}, channels={channels}")
    _check_envelope(taps, wl, shift)
    if form is None and jax.default_backend() != "cpu" \
            and channels * n * taps > _DOT_WINDOW_BUDGET:
        form = "rows"     # keep the streaming kernel: the (C, N, taps)
        #                   windowed operand would defeat its VMEM budget
    if resolve_form(form) == "dot":
        return _fir_bank_dotform(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                 shift=shift, windowed=windowed)

    bc = min(bc, channels)
    bt = min(bt, n)
    nc = pl.cdiv(channels, bc)
    nt = pl.cdiv(n, bt)
    # tail padding only; the taps-1 history halo travels through scratch
    xp = jnp.pad(x, ((0, nc * bc - channels), (0, nt * bt - n)))
    pad_c = ((0, 0), (0, nc * bc - channels), (0, 0))
    hmp = jnp.pad(hmag, pad_c)
    hsp = jnp.pad(hneg, pad_c)

    kernel = functools.partial(_fir_bank_kernel, wl=wl, vbl=vbl, kind=kind,
                               taps=taps, shift=shift, bt=bt)
    plane_spec = pl.BlockSpec((n_rows, bc, taps), lambda c, t: (0, c, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nc, nt),
        in_specs=[
            pl.BlockSpec((bc, bt), lambda c, t: (c, t)),
            plane_spec,
            plane_spec,
        ],
        out_specs=pl.BlockSpec((bc, bt), lambda c, t: (c, t)),
        out_shape=jax.ShapeDtypeStruct((nc * bc, nt * bt), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bc, taps - 1), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xp, hmp, hsp)
    return out[:channels, :n]


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "shift",
                                             "bc", "bt", "interpret",
                                             "form"))
def fir_bbm_bank(x, h, *, wl: int, vbl: int, kind: int = 0, shift: int = 0,
                 bc: int = 8, bt: int = 512, interpret: bool = False,
                 form: str | None = None):
    """Bit-exact Broken-Booth FIR filterbank from raw tap codes.

    x: (C, N) int32 wl-bit signal codes, one row per channel.
    h: (C, taps) int32 wl-bit tap codes (per-channel banks) or (taps,)
       to share one bank across all channels.
    Returns (C, N) int32 accumulator values (sum of shifted products).

    Thin raw-code wrapper: precodes ``h`` once (outside the grid) and
    dispatches to ``fir_bbm_bank_precoded``.
    """
    channels = x.shape[0]
    if h.ndim == 1:
        h = jnp.broadcast_to(h[None, :], (channels, h.shape[0]))
    hmag, hneg = booth_precode(h, wl)
    return fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                 shift=shift, bc=bc, bt=bt,
                                 interpret=interpret, form=form)


def fir_bbm(x, h, *, wl: int, vbl: int, kind: int = 0, shift: int = 0,
            block: int = 512, interpret: bool = False,
            form: str | None = None):
    """Single-channel Broken-Booth FIR: x (N,) codes, h (taps,) codes.

    Thin wrapper over the (channels, time) filterbank kernel with C = 1.
    """
    return fir_bbm_bank(x[None, :], h[None, :], wl=wl, vbl=vbl, kind=kind,
                        shift=shift, bc=1, bt=block, interpret=interpret,
                        form=form)[0]
