"""Subsystem package."""
