"""Deterministic synthetic token pipeline — stateless, resumable, elastic.

Batch content is a pure function of (seed, step, global position), so:
  * restart at step N reproduces exactly the batches a crashed run saw,
  * re-sharding to a different host/device count changes nothing (each host
    materializes only its slice of the same global batch),
  * no filesystem or service dependency in CI.

The token stream is a mixture of Zipf-ish unigram draws and a repeated-
n-gram process, which gives language-like compressible structure (loss
actually decreases during the example trainings rather than sitting at
log V).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["DataConfig", "global_batch", "host_shard", "batches"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ngram: int = 8


def _rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, 0xD15EA5E]))


def global_batch(cfg: DataConfig, step: int) -> Tuple[np.ndarray, np.ndarray]:
    """(tokens, labels) for one global step, shape (B, S) int32."""
    rng = _rng(cfg, step)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab
    # zipf-ish unigrams
    ranks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
    toks = (ranks - 1) % v
    # overlay repeated n-grams for learnable structure
    n_rep = max(1, s // (4 * cfg.ngram))
    motif = rng.integers(0, v, size=(b, cfg.ngram))
    for i in range(n_rep):
        pos = rng.integers(0, s + 1 - cfg.ngram, size=b)
        for row in range(b):
            toks[row, pos[row]:pos[row] + cfg.ngram] = motif[row]
    toks = toks.astype(np.int32)
    return toks[:, :-1], toks[:, 1:]


def host_shard(arr: np.ndarray, host_id: int, n_hosts: int) -> np.ndarray:
    """The slice of the global batch this host feeds to its local devices."""
    b = arr.shape[0]
    assert b % n_hosts == 0
    per = b // n_hosts
    return arr[host_id * per:(host_id + 1) * per]


def batches(cfg: DataConfig, start_step: int = 0,
            host_id: int = 0, n_hosts: int = 1) -> Iterator:
    step = start_step
    while True:
        toks, labels = global_batch(cfg, step)
        yield (host_shard(toks, host_id, n_hosts),
               host_shard(labels, host_id, n_hosts), step)
        step += 1
