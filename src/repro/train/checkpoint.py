"""Sharded checkpointing: manifest + per-leaf .npy, async writer, elastic
restore (a checkpoint written on mesh A loads onto mesh B — the host arrays
are resharded by device_put against B's shardings).

Layout:
    <dir>/step_000123/
        MANIFEST.json        {step, leaf paths, dtypes, shapes, done: true}
        <leaf-key>.npy
The ``done`` flag is written last — a crash mid-write leaves a restorable
previous checkpoint (restore picks the newest *complete* step).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "gc_old"]


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_name(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def _to_savable(arr: np.ndarray):
    """numpy can't round-trip ml_dtypes (bf16 etc) — store a u16/u8 view."""
    if arr.dtype.kind not in "fiub" or str(arr.dtype) in (
            "bfloat16", "float8_e4m3fn", "float8_e5m2"):
        itemsize = arr.dtype.itemsize
        view_dtype = {1: np.uint8, 2: np.uint16, 4: np.uint32}[itemsize]
        return arr.view(view_dtype), str(arr.dtype)
    return arr, str(arr.dtype)


def _from_savable(arr: np.ndarray, dtype_str: str):
    if str(arr.dtype) != dtype_str:
        import ml_dtypes
        return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))
    return arr


def save(tree, step: int, ckpt_dir: str, *, keep: int = 3) -> str:
    """Synchronous checkpoint write.  Returns the step directory."""
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    names = []
    logical_dtypes = []
    for i, arr in enumerate(host):
        savable, dts = _to_savable(arr)
        logical_dtypes.append(dts)
        np.save(os.path.join(tmp, _leaf_name(i)), savable)
        names.append(_leaf_name(i))
    manifest = {
        "step": step,
        "leaves": names,
        "treedef": str(treedef),
        "shapes": [list(a.shape) for a in host],
        "dtypes": logical_dtypes,
        "done": True,
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(d):
        shutil.rmtree(d)
    os.rename(tmp, d)
    gc_old(ckpt_dir, keep=keep)
    return d


_pending: list = []


def save_async(tree, step: int, ckpt_dir: str, *, keep: int = 3):
    """Fire-and-forget checkpoint on a writer thread (device_get happens on
    the caller thread so the arrays are snapshot-consistent)."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    snapshot = jax.tree.unflatten(treedef, host)
    t = threading.Thread(target=save, args=(snapshot, step, ckpt_dir),
                         kwargs={"keep": keep}, daemon=True)
    t.start()
    _pending.append(t)
    return t


def wait_pending():
    for t in _pending:
        t.join()
    _pending.clear()


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        mf = os.path.join(ckpt_dir, name, "MANIFEST.json")
        if not os.path.exists(mf):
            continue
        try:
            if json.load(open(mf)).get("done"):
                s = int(m.group(1))
                best = s if best is None else max(best, s)
        except (json.JSONDecodeError, OSError):
            continue
    return best


def restore(tree_like, ckpt_dir: str, *, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``tree_like``.

    shardings: optional matching tree of NamedShardings — this is the
    elastic-rescale path: host arrays are device_put against the *new*
    mesh's shardings regardless of what mesh wrote them.
    Returns (tree, step) or (None, None) if nothing to restore.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    manifest = json.load(open(os.path.join(d, "MANIFEST.json")))
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == len(manifest["leaves"]), \
        "checkpoint/model structure mismatch"
    host = [_from_savable(np.load(os.path.join(d, n)), dt)
            for n, dt in zip(manifest["leaves"], manifest["dtypes"])]
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        host = [jax.device_put(a, s) for a, s in zip(host, sh_leaves)]
    else:
        host = [jax.numpy.asarray(a) for a in host]
    return jax.tree.unflatten(treedef, host), step


def gc_old(ckpt_dir: str, *, keep: int = 3):
    steps = []
    if not os.path.isdir(ckpt_dir):
        return
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    for s in sorted(steps)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)
