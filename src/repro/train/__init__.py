"""Subsystem package."""
