"""Distributed train step: microbatched grad accumulation + AdamW update.

The step is a single jit-compiled function whose in/out shardings come from
the logical rules (parallel/logical.py):

  * params/opt-state sharded by their logical axes (FSDP embed axis over
    data, TP over model, opt state additionally over pod),
  * the batch sharded over (pod, data),
  * gradient accumulation over ``microbatches`` via lax.scan (activation
    memory / microbatches),
  * remat on the layer scan (ModelRuntime.remat),
  * donate_argnums on (params, opt_state) so XLA reuses their buffers.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import ModelRuntime, lm_loss, lm_logical_axes
from ..parallel.logical import (OPT_RULES, OPT_RULES_MULTIPOD, RULES,
                                RULES_MULTIPOD, batch_pspec, is_multipod,
                                tree_shardings)
from .optimizer import OptConfig, OptState, apply_updates, init_opt

__all__ = ["TrainConfig", "make_train_step", "train_step_shardings",
           "loss_and_grads"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)
    param_dtype: Any = jnp.float32


def loss_and_grads(params, cfg: ArchConfig, rt: ModelRuntime, tokens,
                   labels, rng, *, microbatches: int = 1,
                   encoder_embeds=None):
    """Microbatched mean loss + grads via scan accumulation."""
    def lf(p, tb, lb, key, enc):
        total, metrics = lm_loss(p, cfg, rt, tb, lb, rng=key,
                                 encoder_embeds=enc)
        return total, metrics

    if microbatches == 1:
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            params, tokens, labels, rng, encoder_embeds)
        return loss, grads, metrics

    b = tokens.shape[0]
    assert b % microbatches == 0
    mb = b // microbatches
    tok_mb = tokens.reshape(microbatches, mb, -1)
    lab_mb = labels.reshape(microbatches, mb, -1)
    enc_mb = (encoder_embeds.reshape((microbatches, mb)
                                     + encoder_embeds.shape[1:])
              if encoder_embeds is not None else None)
    keys = jax.random.split(rng, microbatches)

    def body(carry, xs):
        loss_acc, grad_acc = carry
        tb, lb, key, enc = xs
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            params, tb, lb, key, enc)
        grad_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                grad_acc, grads)
        return (loss_acc + loss, grad_acc), metrics

    zero_grads = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads), metrics = jax.lax.scan(
        body, (jnp.float32(0.0), zero_grads),
        (tok_mb, lab_mb, keys, enc_mb))
    inv = 1.0 / microbatches
    grads = jax.tree.map(lambda g: g * inv, grads)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum * inv, grads, metrics


def train_step_shardings(cfg: ArchConfig, mesh: Mesh,
                         global_batch: Optional[int] = None):
    """(param_shardings, opt_shardings, batch_sharding) for the mesh."""
    from ..models import lm_table
    axes = lm_logical_axes(cfg)
    table = lm_table(cfg)
    mp = is_multipod(mesh)
    p_rules = RULES_MULTIPOD if mp else RULES
    o_rules = OPT_RULES_MULTIPOD if mp else OPT_RULES
    p_sh = tree_shardings(axes, mesh, p_rules, shapes_tree=table)
    o_sh = tree_shardings(axes, mesh, o_rules, shapes_tree=table)
    b_sh = NamedSharding(mesh, batch_pspec(mesh, global_batch))
    return p_sh, o_sh, b_sh


def make_train_step(cfg: ArchConfig, rt: ModelRuntime, tc: TrainConfig,
                    mesh: Mesh, *, with_encoder: bool = False,
                    global_batch: Optional[int] = None):
    """Build the jitted train step with explicit in/out shardings."""
    p_sh, o_sh, b_sh = train_step_shardings(cfg, mesh, global_batch)
    opt_sh = OptState(NamedSharding(mesh, P()), o_sh, o_sh)
    rng_sh = NamedSharding(mesh, P())

    def step(params, opt_state, tokens, labels, rng, encoder_embeds=None):
        loss, grads, metrics = loss_and_grads(
            params, cfg, rt, tokens, labels, rng,
            microbatches=tc.microbatches, encoder_embeds=encoder_embeds)
        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, opt_state, tc.opt)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return new_params, new_opt, metrics

    in_sh = [p_sh, opt_sh, b_sh, b_sh, rng_sh]
    if with_encoder:
        in_sh.append(b_sh)
    metrics_sh = None  # let xla choose for the scalar dict
    return jax.jit(
        step,
        in_shardings=tuple(in_sh),
        out_shardings=(p_sh, opt_sh, metrics_sh),
        donate_argnums=(0, 1),
    )


def init_train_state(cfg: ArchConfig, tc: TrainConfig, mesh: Mesh, key):
    """Host-side init then device_put with the target shardings."""
    from ..models import lm_init
    p_sh, o_sh, _ = train_step_shardings(cfg, mesh)
    params = lm_init(cfg, key, tc.param_dtype)
    params = jax.device_put(params, p_sh)
    opt = init_opt(params, tc.opt)
    opt = OptState(jax.device_put(opt.step, NamedSharding(mesh, P())),
                   jax.device_put(opt.m, o_sh),
                   jax.device_put(opt.v, o_sh))
    return params, opt
