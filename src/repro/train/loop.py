"""Fault-tolerant training loop.

Production behaviours implemented and exercised in tests/examples:
  * periodic async checkpointing with atomic rename + done-flag,
  * automatic resume from the newest complete checkpoint,
  * step-level retry: a transient failure (injectable for tests via
    ``failure_hook``) restores params/opt from the last checkpoint and
    replays — the deterministic data pipeline guarantees identical batches,
  * straggler monitor: per-step wall time EMA + z-score; slow steps are
    logged and counted (on real fleets the hook triggers hot-spare swap /
    elastic downscale; here the policy decision is surfaced to the caller),
  * elastic rescale: ``restore`` takes the *new* mesh's shardings, so a
    checkpoint written on 512 devices restarts on 256 (tests cover a 1<->2
    device version of this).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from . import checkpoint as ckpt

__all__ = ["LoopConfig", "StragglerMonitor", "train_loop"]


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_retries: int = 3
    log_every: int = 10


class StragglerMonitor:
    """Flags steps whose wall time is a z-score outlier vs the EMA."""

    def __init__(self, alpha: float = 0.05, z_thresh: float = 3.0):
        self.alpha = alpha
        self.z = z_thresh
        self.mean = None
        self.var = 0.0
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        if self.mean is None:
            self.mean = dt
            return False
        z = (dt - self.mean) / max(np.sqrt(self.var), 1e-6)
        slow = bool(self.var > 0 and z > self.z)
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        if slow:
            self.flagged += 1
        return slow


def train_loop(step_fn: Callable, params, opt_state, data_iter,
               cfg: LoopConfig, *, rng, shardings=None,
               failure_hook: Optional[Callable[[int], None]] = None,
               log_fn: Callable[[str], None] = print):
    """Run the loop with checkpoint/restart fault tolerance.

    step_fn(params, opt, tokens, labels, rng) -> (params, opt, metrics)
    failure_hook(step): test injection point — raising inside it simulates
    a node failure at that step.
    Returns (params, opt_state, history).
    """
    state_tree = {"params": params, "opt": opt_state}
    restored, at = ckpt.restore(state_tree, cfg.ckpt_dir,
                                shardings=shardings)
    start = 0
    if restored is not None:
        params, opt_state = restored["params"], restored["opt"]
        start = at + 1
        log_fn(f"[loop] resumed from checkpoint step {at}")

    monitor = StragglerMonitor()
    history = []
    step = start
    retries = 0
    data = iter(data_iter(start))
    while step < cfg.total_steps:
        tokens, labels, data_step = next(data)
        assert data_step == step, "data pipeline out of sync"
        t0 = time.perf_counter()
        try:
            if failure_hook is not None:
                failure_hook(step)
            key = jax.random.fold_in(rng, step)
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 tokens, labels, key)
            jax.block_until_ready(metrics["loss"])
        except Exception as e:  # noqa: BLE001 — node failure semantics
            retries += 1
            if retries > cfg.max_retries:
                raise
            log_fn(f"[loop] step {step} failed ({type(e).__name__}: {e}); "
                   f"restoring last checkpoint (retry {retries})")
            restored, at = ckpt.restore(state_tree, cfg.ckpt_dir,
                                        shardings=shardings)
            if restored is not None:
                params, opt_state = restored["params"], restored["opt"]
                step = at + 1
            else:
                step = 0
            data = iter(data_iter(step))
            continue
        dt = time.perf_counter() - t0
        slow = monitor.observe(dt)
        if slow:
            log_fn(f"[loop] step {step}: straggler flagged ({dt*1e3:.1f} ms)")
        history.append({"step": step, "loss": float(metrics["loss"]),
                        "dt": dt, "straggler": slow})
        if step % cfg.log_every == 0:
            log_fn(f"[loop] step {step} loss {float(metrics['loss']):.4f} "
                   f"({dt*1e3:.1f} ms)")
        if cfg.ckpt_every and step % cfg.ckpt_every == 0 and step > start:
            ckpt.save_async({"params": params, "opt": opt_state}, step,
                            cfg.ckpt_dir, keep=cfg.keep)
        step += 1
    ckpt.wait_pending()
    ckpt.save({"params": params, "opt": opt_state}, cfg.total_steps - 1,
              cfg.ckpt_dir, keep=cfg.keep)
    return params, opt_state, history
