"""Optimizers (AdamW, Adafactor-lite) + schedules, pure JAX pytrees.

Optimizer state dtype is configurable: fp32 (default) or bf16 ("quantized
optimizer state" — halves the dominant memory term at 671B; see
docs/perf.md §Model-side perf levers).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "OptState", "init_opt", "apply_updates",
           "warmup_cosine", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10000


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init_opt(params, cfg: OptConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    if cfg.kind == "adafactor":
        # factored second moment: row/col accumulators for >=2D params
        def fac(p):
            if p.ndim >= 2:
                return (jnp.zeros(p.shape[:-1], cfg.state_dtype),
                        jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                  cfg.state_dtype))
            return jnp.zeros(p.shape, cfg.state_dtype)
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(zeros, params),
                        jax.tree.map(fac, params))
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(zeros, params),
                    jax.tree.map(zeros, params))


def warmup_cosine(cfg: OptConfig):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(cfg.warmup_steps, 1)
        prog = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * jnp.where(step < cfg.warmup_steps, warm,
                                  0.1 + 0.9 * cos)
    return sched


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def apply_updates(params, grads, state: OptState, cfg: OptConfig
                  ) -> Tuple[Any, OptState, dict]:
    """One optimizer step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = warmup_cosine(cfg)(step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    if cfg.kind == "adafactor":
        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            if p.ndim >= 2:
                vr, vc = v
                vr32 = (cfg.b2 * vr.astype(jnp.float32)
                        + (1 - cfg.b2) * jnp.mean(g32 * g32, axis=-1))
                vc32 = (cfg.b2 * vc.astype(jnp.float32)
                        + (1 - cfg.b2) * jnp.mean(g32 * g32, axis=-2))
                rms = jnp.sqrt(
                    vr32[..., :, None] * vc32[..., None, :]
                    / jnp.maximum(jnp.mean(vr32, axis=-1,
                                           keepdims=True)[..., None], 1e-30))
                upd_ = g32 / jnp.maximum(jnp.sqrt(rms), cfg.eps)
                new_v = (vr32.astype(cfg.state_dtype),
                         vc32.astype(cfg.state_dtype))
            else:
                v32 = (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2)
                       * g32 * g32)
                upd_ = g32 / (jnp.sqrt(v32 / bc2) + cfg.eps)
                new_v = v32.astype(cfg.state_dtype)
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * upd_
            newp = (p.astype(jnp.float32) - lr * (m32 / bc1)
                    - lr * cfg.weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), m32.astype(cfg.state_dtype), new_v

        out = jax.tree.map(upd, params, grads, state.m, state.v,
                           is_leaf=lambda x: isinstance(x, tuple)
                           and not isinstance(x, jax.Array))
        newp = jax.tree.map(lambda t3: t3[0], out,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 3)
        newm = jax.tree.map(lambda t3: t3[1], out,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 3)
        newv = jax.tree.map(lambda t3: t3[2], out,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 3)
        return newp, OptState(step, newm, newv), {"lr": lr, "gnorm": gnorm}

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        newp = (p.astype(jnp.float32) - lr * u
                - lr * cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), m32.astype(cfg.state_dtype), \
            v32.astype(cfg.state_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    res = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    newp = tdef.unflatten([r[0] for r in res])
    newm = tdef.unflatten([r[1] for r in res])
    newv = tdef.unflatten([r[2] for r in res])
    return newp, OptState(step, newm, newv), {"lr": lr, "gnorm": gnorm}
