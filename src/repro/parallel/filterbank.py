"""Channel-sharded Broken-Booth FIR filterbank (shard_map over the mesh).

Channels are embarrassingly parallel in the filterbank: y[c] depends only
on x[c] and h[c].  ``sharded_filterbank`` splits the channel axis across a
mesh axis with ``shard_map`` and runs the single-device datapath on each
shard — the Pallas kernel on TPU, the pure-jnp closed form elsewhere — so a
(C, N) batch is served by ``mesh.shape[axis]`` devices with no collectives
at all (the sharding *is* the decomposition).

The tap bank is the Booth multiplier operand and is constant across the
batch, so its radix-4 digits are decoded exactly once — *outside* the
shard_map — and the (wl//2, C, taps) digit planes are what gets sharded
along the channel axis; each shard runs the accumulate phase only.
Long-lived callers can decode once per bank lifetime with
``precode_filterbank`` and pass the planes to every call.

Accumulate-form selection is per shard and trace-time: the dot form
(dense exact contraction on the matmul units + scaled truncated rows —
``kernels.booth_rows``) is the default on every backend; ``form="rows"``
pins the streaming kernel emulation instead.

Everything is integer-code level: (C, N) int32 wl-bit signal codes in,
(C, N) int32 accumulator values out, bit-identical to the unsharded kernel
because each channel's computation is untouched by the split.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels.booth_rows import booth_precode, resolve_form
from ..kernels.fir_kernel import (_DOT_WINDOW_BUDGET, _check_envelope,
                                  fir_bbm_bank_precoded)
from ..kernels.ops import on_tpu
from ..kernels.ref import fir_bank_ref

__all__ = ["precode_filterbank", "sharded_filterbank"]


def precode_filterbank(h, *, wl: int, channels: int | None = None):
    """Decode a (C, taps) tap bank once -> (hmag, hneg) digit planes.

    h: (C, taps) int32 codes, or (taps,) to share one bank across
    ``channels`` rows.  The planes feed ``sharded_filterbank(h_planes=...)``
    across any number of calls that reuse the bank.
    """
    h = jnp.asarray(h)
    if h.ndim == 1:
        if channels is None:
            raise ValueError("channels is required to broadcast a shared "
                             "(taps,) bank")
        h = jnp.broadcast_to(h[None, :], (channels, h.shape[0]))
    return booth_precode(h, wl)


def sharded_filterbank(x, h, mesh: Mesh, *, wl: int, vbl: int, kind: int = 0,
                       shift: int = 0, axis: str = "data",
                       use_kernel: bool | None = None, bc: int = 8,
                       bt: int = 512, h_planes=None,
                       form: str | None = None):
    """Filterbank over ``mesh`` with channels sharded on mesh axis ``axis``.

    x: (C, N) int32 codes, h: (C, taps) int32 codes (or (taps,) shared).
    C must divide by the mesh axis size; pad channels first if it does not.
    ``use_kernel=None`` picks the kernel datapath everywhere: on TPU
    always, and off-TPU because the auto form is the dot form — plain
    XLA, not the interpreter.  Only ``form="rows"`` off-TPU falls back to
    the jnp closed form (the interpreter inside shard_map would only slow
    things down); ``use_kernel=False`` forces that path.  ``form`` pins
    the accumulate form ("rows"/"dot"; None auto).  ``h_planes`` takes
    the digit planes from ``precode_filterbank`` so a long-lived bank is
    decoded once, not once per call; when omitted the decode still runs
    only once per call, outside the shard_map.
    """
    from jax.experimental.shard_map import shard_map

    if h.ndim == 1:
        h = jnp.broadcast_to(h[None, :], (x.shape[0], h.shape[0]))
    # the kernel path checks this itself; the closed-form host path would
    # silently wrap int32 instead — guard both uniformly
    _check_envelope(h.shape[1], wl, shift)
    n_shards = mesh.shape[axis]
    if x.shape[0] % n_shards:
        raise ValueError(f"channels={x.shape[0]} not divisible by "
                         f"mesh axis {axis!r} of size {n_shards}")
    resolve_form(form)        # validate on every path, incl. the jnp one
    if use_kernel is None:
        # auto: the kernel datapath, unless a form=None off-TPU shard
        # would hit the kernel's own auto-form memory fallback to
        # *interpreted* rows — there the jnp closed form below is the
        # sane default instead.  An explicit form="dot" is always
        # honored (the caller owns the memory then).
        per_shard = (x.shape[0] // n_shards) * x.shape[1] * h.shape[1]
        dot_auto = resolve_form(form) == "dot" and (
            form == "dot"
            or jax.default_backend() == "cpu"
            or per_shard <= _DOT_WINDOW_BUDGET)
        use_kernel = on_tpu() or dot_auto

    if use_kernel:
        if h_planes is None:
            h_planes = booth_precode(h, wl)     # once, outside the shard_map
        hmag, hneg = h_planes
        if hmag.shape[1] != x.shape[0]:
            raise ValueError(f"h_planes cover {hmag.shape[1]} channels, "
                             f"x has {x.shape[0]}")
        apply_fn = functools.partial(fir_bbm_bank_precoded, wl=wl, vbl=vbl,
                                     kind=kind, shift=shift, bc=bc, bt=bt,
                                     interpret=not on_tpu(), form=form)
        fn = shard_map(
            lambda xs, hm, hn: apply_fn(xs, hm, hn),
            mesh=mesh,
            in_specs=(P(axis, None), P(None, axis, None),
                      P(None, axis, None)),
            out_specs=P(axis, None),
            check_rep=False,
        )
        return fn(x, hmag, hneg)

    fn = shard_map(
        lambda xs, hs: fir_bank_ref(xs, hs, wl=wl, vbl=vbl, kind=kind,
                                    shift=shift),
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=P(axis, None),
        check_rep=False,
    )
    return fn(x, h)
