"""Compressed gradient all-reduce (distributed-optimization trick).

Two codecs, both with error feedback (the residual of one step is added
back before the next quantization, so compression error does not bias the
trajectory — it behaves like the paper's white noise source):

  "int8"    — blockwise-scaled int8 with deterministic-stochastic rounding
              (counter-hash), 4x reduction over fp32 on the wire
  "bf16"    — mantissa truncation: the paper's VBL idea applied to the
              communication payload (drop the low 16 mantissa bits)

Implemented as a shard_map over the data axis so the quantize -> psum ->
dequantize pipeline is explicit (XLA cannot fuse through a psum dtype
change on its own).  The pure-jax reference path (`allreduce_ref`) backs the
tests; multi-device behaviour is exercised in tests/test_parallel.py via a
subprocess with forced host devices.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["compress_decompress", "compressed_allreduce", "allreduce_ref"]

BLOCK = 256


def _block_scale(x2d):
    s = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True) / 127.0
    return jnp.maximum(s, 1e-12)


def compress_decompress(g, codec: str, key=None):
    """One round-trip through the codec (for error-feedback bookkeeping)."""
    if codec == "bf16":
        return g.astype(jnp.bfloat16).astype(g.dtype)
    if codec == "int8":
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % BLOCK
        fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
        s = _block_scale(fp)
        scaled = fp / s
        if key is not None:
            noise = jax.random.uniform(key, scaled.shape) - 0.5
            q = jnp.clip(jnp.round(scaled + noise), -127, 127)
        else:
            q = jnp.clip(jnp.round(scaled), -127, 127)
        out = (q.astype(jnp.int8).astype(jnp.float32) * s).reshape(-1)
        return out[:flat.shape[0]].reshape(g.shape).astype(g.dtype)
    raise ValueError(codec)


def allreduce_ref(gs_stacked, codec: str):
    """Reference: mean over a stacked leading 'device' axis, each shard
    compressed before the sum (what the shard_map path computes)."""
    comp = jax.vmap(lambda g: compress_decompress(g, codec))(gs_stacked)
    return jnp.mean(comp, axis=0)


def compressed_allreduce(grads, mesh: Mesh, codec: str = "int8",
                         axis: str = "data", error_buf=None):
    """All-reduce-mean `grads` over `axis` with on-the-wire compression.

    grads must be replicated-or-sharded consistently with the mesh; the
    shard_map treats each leaf as locally owned and psums the quantized
    payload.  Returns (mean_grads, new_error_buf).
    """
    from jax.experimental.shard_map import shard_map

    if error_buf is None:
        error_buf = jax.tree.map(jnp.zeros_like, grads)

    def per_shard(g, e):
        g_fb = g + e
        if codec == "int8":
            flat = g_fb.reshape(-1)
            pad = (-flat.shape[0]) % BLOCK
            fp = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
            # shared per-block scale (pmax = one tiny fp32 collective) so
            # the int8 sums decode exactly: sum(q_i) * s / n == mean
            s = jax.lax.pmax(_block_scale(fp), axis)
            q = jnp.clip(jnp.round(fp / s), -127, 127).astype(jnp.int8)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            n = jax.lax.psum(1, axis)
            mean = (qsum.astype(jnp.float32) * s / n).reshape(-1)
            mean = mean[:flat.shape[0]].reshape(g.shape).astype(g.dtype)
            sent = (q.astype(jnp.float32) * s).reshape(-1)
            sent = sent[:flat.shape[0]].reshape(g.shape)
        else:
            comp = g_fb.astype(jnp.bfloat16)
            mean = (jax.lax.psum(comp.astype(jnp.float32), axis)
                    / jax.lax.psum(1, axis)).astype(g.dtype)
            sent = comp.astype(jnp.float32)
        new_e = (g_fb - sent).astype(e.dtype)
        return mean, new_e

    def inner(g_tree, e_tree):
        leaves_g, tdef = jax.tree.flatten(g_tree)
        leaves_e = tdef.flatten_up_to(e_tree)
        res = [per_shard(g, e) for g, e in zip(leaves_g, leaves_e)]
        return (tdef.unflatten([m for m, _ in res]),
                tdef.unflatten([e2 for _, e2 in res]))

    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(P(axis), P(axis)),      # leading dim owned per data shard
        out_specs=(P(axis), P(axis)),
        check_rep=False,
    )
    return fn(grads, error_buf)
