"""Distribution layer: logical sharding rules, compressed collectives, and
the channel-sharded FIR filterbank."""
from .filterbank import sharded_filterbank

__all__ = ["sharded_filterbank"]
