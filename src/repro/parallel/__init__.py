"""Distribution layer: logical sharding rules, compressed collectives, and
the channel-sharded FIR filterbank."""
from .filterbank import precode_filterbank, sharded_filterbank

__all__ = ["precode_filterbank", "sharded_filterbank"]
