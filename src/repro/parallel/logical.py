"""Logical-axis sharding rules (MaxText-style).

Every parameter/activation axis carries a *logical* name; rules map logical
names to mesh axes.  GSPMD pads non-divisible dimensions (e.g. 14 query
heads on a 16-way "model" axis), so one rule set serves all ten assigned
architectures on the fixed production mesh.

Rule sets:
  RULES               single-pod (data, model)
  RULES_MULTIPOD      two-pod (pod, data, model): batch gains the pod axis,
                      parameters stay pod-replicated (data-parallel pods)
  OPT_RULES(_MULTIPOD) optimizer-state rules: identical except the "embed"
                      axis also shards over the pod axis (ZeRO across pods —
                      optimizer state is the memory hog at 671B)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["RULES", "RULES_MULTIPOD", "OPT_RULES", "OPT_RULES_MULTIPOD",
           "spec_to_pspec", "tree_shardings", "logical_sharding",
           "batch_pspec", "is_multipod"]

RULES: Dict[Optional[str], Any] = {
    "batch": "data",
    "seq": None,
    "embed": "data",          # FSDP: weight embed axis sharded over data
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",       # expert parallelism
    "expert_mlp": None,
    "vocab": "model",
    "q_latent": "model",
    "kv_latent": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,
    None: None,
}

RULES_MULTIPOD = dict(RULES, batch=("pod", "data"))
OPT_RULES = dict(RULES)
OPT_RULES_MULTIPOD = dict(RULES_MULTIPOD, embed=("pod", "data"))

# When a primary axis cannot shard (non-divisible, e.g. grok's 8 experts on
# a 16-way model axis), a fallback logical axis of the same spec may claim
# the freed mesh axis: TP-experts instead of EP (it-E in EXPERIMENTS §Perf).
FALLBACK_RULES: Dict[str, Any] = {
    "expert_mlp": "model",
}


def is_multipod(mesh: Mesh) -> bool:
    return "pod" in mesh.axis_names


def spec_to_pspec(axes: Tuple[Optional[str], ...], rules: Dict,
                  shape: Optional[Tuple[int, ...]] = None,
                  mesh: Optional[Mesh] = None) -> P:
    """Resolve logical axes to a PartitionSpec.

    When ``shape``/``mesh`` are given, every candidate mesh axis must evenly
    divide its dimension; non-divisible axes are dropped (replicated) —
    pjit's explicit in_shardings reject uneven sharding, and this is what
    makes one rule set serve qwen2's 14 heads and the long_500k batch of 1
    on the same 16x16 mesh.
    """
    entries = []
    used = set()
    for i, a in enumerate(axes):
        r = rules.get(a, None)
        if r is None:
            entries.append(None)
            continue
        rr = tuple(r) if isinstance(r, (tuple, list)) else (r,)
        # a mesh axis may appear only once per spec; later dims fall back
        # to replication (e.g. (experts->model, embed->data, mlp->None))
        rr = tuple(x for x in rr if x not in used)
        if shape is not None and mesh is not None:
            keep = []
            rem = shape[i]
            for ax in rr:
                sz = mesh.shape[ax]
                if rem % sz == 0:
                    keep.append(ax)
                    rem //= sz
            rr = tuple(keep)
        used.update(rr)
        entries.append(rr if len(rr) > 1 else (rr[0] if rr else None))
    # second pass: fallback axes may claim mesh axes freed by non-divisible
    # primaries (e.g. expert_mlp takes "model" when 8 experts can't)
    for i, a in enumerate(axes):
        fb = FALLBACK_RULES.get(a)
        if fb is None or entries[i] is not None or fb in used:
            continue
        if shape is not None and mesh is not None \
                and shape[i] % mesh.shape[fb] != 0:
            continue
        entries[i] = fb
        used.add(fb)
    return P(*entries)


def tree_shardings(axes_tree, mesh: Mesh, rules: Optional[Dict] = None,
                   shapes_tree=None):
    """Map a tree of logical-axis tuples to NamedShardings.

    shapes_tree: optional matching tree of objects with ``.shape`` (Specs or
    ShapeDtypeStructs) enabling the divisibility check.
    """
    if rules is None:
        rules = RULES_MULTIPOD if is_multipod(mesh) else RULES
    is_axes = lambda x: isinstance(x, tuple)
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, spec_to_pspec(axes, rules)),
            axes_tree, is_leaf=is_axes)
    return jax.tree.map(
        lambda axes, s: NamedSharding(
            mesh, spec_to_pspec(axes, rules, tuple(s.shape), mesh)),
        axes_tree, shapes_tree, is_leaf=is_axes)


def logical_sharding(mesh: Mesh, *axes, rules: Optional[Dict] = None,
                     shape=None):
    if rules is None:
        rules = RULES_MULTIPOD if is_multipod(mesh) else RULES
    return NamedSharding(mesh, spec_to_pspec(tuple(axes), rules, shape, mesh))


def batch_pspec(mesh: Mesh, batch: Optional[int] = None) -> P:
    axes = ("pod", "data") if is_multipod(mesh) else ("data",)
    if batch is not None:
        keep = []
        rem = batch
        for ax in axes:
            if rem % mesh.shape[ax] == 0:
                keep.append(ax)
                rem //= mesh.shape[ax]
        axes = tuple(keep)
    if not axes:
        return P(None)
    return P(axes if len(axes) > 1 else axes[0])
