"""Roofline analysis over the dry-run results (deliverable g).

Reads benchmarks/dryrun_results.json (written by repro.launch.dryrun) and
derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the useful-
compute ratio MODEL_FLOPS / HLO_FLOPs.  cost_analysis() numbers from the
CPU-backend SPMD compile are per-partition; terms are per-chip seconds.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_arch
from repro.launch.mesh import HW

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results.json")


# --------------------------------------------------------- parameter counts
def param_count(arch: str) -> Dict[str, float]:
    """(total, active-per-token) parameter counts from the config."""
    c = get_arch(arch)
    d, v = c.d_model, c.vocab
    hd = c.resolved_head_dim
    emb = v * d * (1 if c.tie_embeddings else 2)
    per_layer_attn = 0.0
    if c.use_mla:
        per_layer_attn = (d * c.q_lora_rank + c.q_lora_rank * c.n_heads
                          * (c.qk_nope_dim + c.qk_rope_dim)
                          + d * (c.kv_lora_rank + c.qk_rope_dim)
                          + c.kv_lora_rank * c.n_heads
                          * (c.qk_nope_dim + c.v_head_dim)
                          + c.n_heads * c.v_head_dim * d)
    elif c.n_heads:
        per_layer_attn = d * hd * (c.n_heads * 2 + c.n_kv_heads * 2)
    mlp_dense = 3 * d * c.d_ff
    total = emb
    active = emb
    if c.family == "moe":
        moe = 3 * d * c.moe_d_ff
        shared = moe * c.n_shared_experts
        n_moe = c.n_layers - c.first_k_dense
        total += (c.first_k_dense * (per_layer_attn + mlp_dense)
                  + n_moe * (per_layer_attn + c.n_experts * moe + shared
                             + d * c.n_experts))
        active += (c.first_k_dense * (per_layer_attn + mlp_dense)
                   + n_moe * (per_layer_attn + c.top_k * moe + shared))
    elif c.family == "ssm":
        di = c.d_inner
        per = (d * (2 * di + 2 * c.ssm_groups * c.ssm_state + c.ssm_heads)
               + di * d)
        total += c.n_layers * per
        active = total
    elif c.family == "hybrid":
        di = c.d_inner
        per = (d * (2 * di + 2 * c.ssm_groups * c.ssm_state + c.ssm_heads)
               + di * d)
        shared_blk = per_layer_attn + mlp_dense
        total += c.n_layers * per + shared_blk
        active = total
    else:
        n_dec = c.n_layers
        total += n_dec * (per_layer_attn + mlp_dense)
        if c.is_encoder_decoder:
            total += (c.n_encoder_layers * (per_layer_attn + mlp_dense)
                      + n_dec * per_layer_attn)   # cross attention
        active = total
    if c.family != "moe":
        active = total
    return {"total": total, "active": active}


def model_flops(arch: str, shape_name: str) -> float:
    """6*N(active)*tokens for the step this cell lowers."""
    sh = SHAPES[shape_name]
    n = param_count(arch)["active"]
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n * tokens          # forward only
    return 2.0 * n * sh.global_batch     # decode: 1 token per row


# ----------------------------------------------------------------- analysis
def analyze(results_path: str = RESULTS,
            mesh: Optional[str] = "16x16") -> List[Dict]:
    with open(results_path) as f:
        data = json.load(f)
    rows = []
    for r in data:
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r.get("mesh"), "ok": False,
                         "error": r.get("error", "?")[:120]})
            continue
        if mesh and r["mesh"] != mesh:
            continue
        chips = r["n_devices"]
        # trip-count-aware costs from the dumped HLO (hlo_analysis.py);
        # XLA's cost_analysis() visits scan bodies once and is only kept
        # as a fallback + diagnostic.
        hlo_path = r.get("hlo_path")
        if hlo_path and os.path.exists(hlo_path):
            from benchmarks.hlo_analysis import analyze_file
            corrected = analyze_file(hlo_path)
            flops = corrected["flops"]
            bytes_acc = corrected["bytes"]
            coll = corrected["collective_bytes"]
        else:
            flops = r["cost"].get("flops", 0.0)
            bytes_acc = r["cost"].get("bytes accessed", 0.0)
            coll = r["collectives"]["total"]
        # cost_analysis on the SPMD-partitioned module is per-partition
        t_compute = flops / HW["peak_flops_bf16"]
        t_memory = bytes_acc / HW["hbm_bw"]
        t_coll = coll / HW["ici_bw"]
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        dominant = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"])
        mf_per_chip = mf / chips
        useful = mf_per_chip / flops if flops else 0.0
        bound = max(terms.values())
        # achievable step time = dominant term (perfect overlap);
        # roofline fraction = useful compute time / bound
        t_useful = mf_per_chip / HW["peak_flops_bf16"]
        frac = t_useful / bound if bound > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "variant": r.get("variant", ""), "ok": True,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops": mf, "hlo_flops_per_chip": flops,
            "useful_ratio": useful, "roofline_frac": frac,
        })
    return rows


def render_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dom':>9s} "
           f"{'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        if not r.get("ok"):
            lines.append(f"{r['arch']:18s} {r['shape']:12s} FAILED: "
                         f"{r.get('error', '')}")
            continue
        lines.append(
            f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute_s']:10.3e} {r['t_memory_s']:10.3e} "
            f"{r['t_collective_s']:10.3e} {r['dominant']:>9s} "
            f"{r['useful_ratio']:7.2f} {r['roofline_frac']:9.3f}")
    return "\n".join(lines)


if __name__ == "__main__":
    rows = analyze()
    print(render_table(rows))
