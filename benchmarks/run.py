"""Benchmark driver: one function per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
whole table's computation; derived = headline comparison vs the paper).
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_tables  # noqa: E402


def _run(name, fn):
    t0 = time.perf_counter()
    rows, derived = fn()
    us = (time.perf_counter() - t0) * 1e6
    short = {k: (round(v, 4) if isinstance(v, float) else v)
             for k, v in derived.items()}
    print(f"{name},{us:.0f},{json.dumps(short, sort_keys=True)}")
    return rows, derived


def main() -> None:
    print("name,us_per_call,derived")
    _run("table1_errstats", paper_tables.table1_errstats)
    _run("fig2_histogram", paper_tables.fig2_histogram)
    _run("table2_3_power_area", paper_tables.table2_3_power_area)
    _run("fig3_power_delay", paper_tables.fig3_power_delay)
    _run("fig56_pdp_mse", paper_tables.fig56_pdp_mse)
    _run("fig8_snr", paper_tables.fig8_snr)
    _run("table4_filter", paper_tables.table4_filter)
    from benchmarks.filterbank import filterbank_sweep
    _run("filterbank_sweep", filterbank_sweep)
    if "--full" in sys.argv:
        from benchmarks.lm_quality import lm_quality
        _run("lm_quality_beyond_paper", lm_quality)

    # roofline summary over whatever dry-run cells exist so far
    try:
        from benchmarks.roofline import analyze
        rows = [r for r in analyze() if r.get("ok")]
        if rows:
            worst = min(rows, key=lambda r: r["roofline_frac"])
            best = max(rows, key=lambda r: r["roofline_frac"])
            doms = {}
            for r in rows:
                doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
            summary = {
                "cells": len(rows),
                "dominant_counts": doms,
                "worst": (f"{worst['arch']}/{worst['shape']}"
                          f"={worst['roofline_frac']:.3f}"),
                "best": (f"{best['arch']}/{best['shape']}"
                         f"={best['roofline_frac']:.3f}"),
            }
            print(f"roofline_summary,0,{json.dumps(summary)}")
            # full per-cell tables (deliverable g): baselines then variants
            from benchmarks.roofline import render_table
            for mesh in ("16x16", "2x16x16"):
                sub = [r for r in analyze(mesh=mesh) if r.get("ok")
                       and not r.get("variant")]
                if sub:
                    print(f"\n== roofline baselines, mesh {mesh} "
                          f"({len(sub)} cells) ==")
                    print(render_table(sub))
            variants = [r for r in analyze(mesh=None) if r.get("ok")
                        and r.get("variant")]
            if variants:
                print(f"\n== roofline perf-iteration variants "
                      f"({len(variants)}) ==")
                hdr = (f"{'arch':18s} {'shape':12s} {'variant':16s} "
                       f"{'compute_s':>10s} {'memory_s':>10s} "
                       f"{'collect_s':>10s} {'roofline':>9s}")
                print(hdr)
                for r in sorted(variants,
                                key=lambda x: (x["arch"], x["variant"])):
                    print(f"{r['arch']:18s} {r['shape']:12s} "
                          f"{r['variant']:16s} {r['t_compute_s']:10.3e} "
                          f"{r['t_memory_s']:10.3e} "
                          f"{r['t_collective_s']:10.3e} "
                          f"{r['roofline_frac']:9.4f}")
    except FileNotFoundError:
        print('roofline_summary,0,{"cells": 0}')


if __name__ == "__main__":
    main()
