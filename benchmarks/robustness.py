"""Robustness benchmark: fault resilience curves + degradation overhead.

Three questions, one artifact (``BENCH_robustness.json``):

  * **Resilience curves** — how gracefully does the Broken-Booth datapath
    degrade under hardware faults, vs the exact Booth datapath on the
    same fault model?  Keyed deterministic faults (``core.faults``) hit
    the Booth digit planes and the int32 accumulator at a sweep of rates;
    the FIR testbed reports SNR_out (paper Fig. 7/8 metric) and the
    matmul path reports relative error vs the float product.  The BBM
    already truncates low-signal structure, so the interesting question
    is whether its curve falls off the same cliff as exact Booth (it
    should: the fault sits in shared row machinery) — the artifact pins
    the answer numerically.
  * **Degradation-path overhead** — what do the serving robustness
    features cost when nothing fails?  ``FilterbankEngine`` flushes the
    same workload with and without retry + runtime guards (including a
    budget audit's extra exact dispatch), and the ratio is the price of
    the guarded path.
  * **CI gate** (``--smoke``) — the contracts the robustness PR claims:
    fault-injected dot form == fault-injected scalar oracle bit for bit
    (plane and accumulator faults), a disabled ``FaultSpec`` is
    bit-identical to the unfaulted datapath, and a poison request is
    quarantined alone while its batch neighbours are served.

SNR is computed against the double-precision reference filter on the
paper's Fig. 7 testbed signals; fault masks are keyed by ``FaultSpec``
seed, so every cell is reproducible bit for bit.
"""
from __future__ import annotations

import json
import os
import platform as platform_mod
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import numpy as np

from repro.core import FaultSpec
from repro.core.faults import apply_plane_faults
from repro.core.guards import GuardConfig
from repro.core.multipliers import MulSpec
from repro.dsp import PrecodedBank, design_lowpass, fir_apply
from repro.dsp.fir import FIR_DELAY, fir_apply_real
from repro.dsp.testbed import make_filterbank_signals, snr_db
from repro.kernels.bbm_matmul import bbm_matmul_dynamic
from repro.kernels.ref import amm_approx_ref, amm_faulty_ref
from repro.serve.engine import FilterbankEngine

FAULT_RATES = [0.0, 1e-4, 1e-3, 1e-2, 1e-1]
SPECS = [MulSpec("bbm0", 16, 13), MulSpec("booth", 16, 0)]


def _faulted_bank(h_banks, spec, fault):
    """PrecodedBank whose cached digit planes carry the injected faults.

    The engine's whole premise is that the planes are decoded once and
    reused — so a stuck/flipped digit line corrupts *every* flush, which
    is exactly the persistent-fault model this injects.
    """
    vbl = 0 if spec.name == "booth" else spec.param
    bank = PrecodedBank(h_banks, spec)
    mag, neg = bank.planes
    bank._planes = apply_plane_faults(mag, neg, fault, vbl=vbl)
    return bank


def fir_resilience(rows, *, n=1 << 12, channels=4):
    """SNR_out vs plane-fault rate, bbm vs exact Booth."""
    sigs = make_filterbank_signals(channels, n=n)
    h_banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    x = np.stack([s.x for s in sigs])
    banks_idx = [c % 2 for c in range(channels)]
    out = {}
    for spec in SPECS:
        curve = []
        for p in FAULT_RATES:
            fault = (FaultSpec(target="plane", model="flip", p=p,
                               lane="all", seed=7) if p else None)
            bank = _faulted_bank(h_banks, spec, fault).take(banks_idx)
            y = fir_apply(x, bank, backend="host", form="dot")
            snrs = [snr_db(sigs[c].d1, y[c], FIR_DELAY)
                    for c in range(channels)]
            snr = float(np.mean(snrs))
            curve.append(snr)
            rows.append({"bench": "fir_snr_vs_fault_rate",
                         "spec": str(spec), "fault_p": p,
                         "mean_snr_db": snr})
        out[spec.name] = curve
    return out


def matmul_resilience(rows, *, m=32, k=192, n=32):
    """Relative matmul error vs fault rate (plane and accumulator)."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    exact = x @ w
    ref_norm = float(np.linalg.norm(exact))
    out = {}
    for spec in SPECS:
        vbl = 0 if spec.name == "booth" else spec.param
        for target, model, kw in [("plane", "flip", {"lane": "all"}),
                                  ("acc", "flip", {"bit": 12})]:
            curve = []
            for p in FAULT_RATES:
                fault = (FaultSpec(target=target, model=model, p=p,
                                   seed=11, **kw) if p else None)
                y = np.asarray(bbm_matmul_dynamic(
                    x, w, wl=spec.wl, vbl=vbl,
                    kind=0, fault=fault))
                rel = float(np.linalg.norm(y - exact) / ref_norm)
                curve.append(rel)
                rows.append({"bench": "matmul_rel_err_vs_fault_rate",
                             "spec": str(spec), "target": target,
                             "fault_p": p, "rel_err": rel})
            out[f"{spec.name}_{target}"] = curve
    return out


def degradation_overhead(rows, *, reqs=8, n=2048, reps=3):
    """Guarded-engine flush time / lean-engine flush time (no failures)."""
    rng = np.random.default_rng(3)
    h = design_lowpass()
    spec = MulSpec("bbm0", 16, 13)
    sigs = [rng.standard_normal(n) for _ in range(reqs)]

    def run(engine_kwargs):
        eng = FilterbankEngine(h, spec, backend="host", **engine_kwargs)
        best = float("inf")
        for _ in range(reps):
            for s in sigs:
                eng.submit(s)
            t0 = time.perf_counter()
            eng.flush()
            best = min(best, time.perf_counter() - t0)
        return best

    lean = run({})
    guarded = run({"max_retries": 2,
                   "guard": GuardConfig(budget_abs=1.0, budget_every=1)})
    ratio = guarded / lean
    rows.append({"bench": "degradation_overhead", "lean_s": lean,
                 "guarded_s": guarded, "overhead_x": ratio})
    return ratio


# ------------------------------------------------------------ smoke gates
def gate_fault_equality() -> int:
    """Faulted dot form == faulted scalar oracle, bit for bit."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((4, 70)).astype(np.float32)
    w = rng.standard_normal((70, 8)).astype(np.float32)
    faults = [None,
              FaultSpec(target="plane", model="flip", p=0.05, seed=3),
              FaultSpec(target="plane", model="stuck1", p=0.05,
                        lane="mag_lo", seed=5),
              FaultSpec(target="acc", model="flip", p=0.3, bit=10, seed=9)]
    for spec in SPECS:
        vbl = 0 if spec.name == "booth" else spec.param
        base = np.asarray(amm_approx_ref(x, w, spec))
        for f in faults:
            got = np.asarray(bbm_matmul_dynamic(x, w, wl=spec.wl, vbl=vbl,
                                                kind=0, fault=f))
            ref = np.asarray(amm_faulty_ref(x, w, spec, fault=f))
            if not np.array_equal(got, ref):
                return 0
            if f is None and not np.array_equal(got, base):
                return 0       # disabled fault must be bit-identical
    return 1


def gate_poison_ejection() -> int:
    """A poison request is quarantined alone; neighbours are served."""
    rng = np.random.default_rng(2)
    eng = FilterbankEngine(design_lowpass(), MulSpec("bbm0", 16, 13),
                           backend="host", max_channels=8, max_retries=1)
    sigs = [rng.standard_normal(128) for _ in range(5)]
    poison = sigs[2]
    inner = eng._apply

    def flaky(x, h, spec, **kw):
        for row in np.asarray(x):
            if np.array_equal(row[:len(poison)], poison):
                raise RuntimeError("injected poison")
        return inner(x, h, spec, **kw)

    eng._apply = flaky
    rids = [eng.submit(s) for s in sigs]
    out = eng.flush()
    ok = (set(out) == set(rids) - {rids[2]}
          and rids[2] in eng.failed
          and not eng._pending
          and eng.flush() == {})   # queue drained: no livelock, no re-raise
    return int(ok)


def robustness(smoke: bool = False, out: str | None = None):
    rows: list = []
    gates = {"fault_equality_bitexact": gate_fault_equality(),
             "poison_ejection": gate_poison_ejection()}
    n = 1 << 10 if smoke else 1 << 12
    fir = fir_resilience(rows, n=n, channels=2 if smoke else 4)
    mm = matmul_resilience(rows, k=70 if smoke else 192)
    overhead = degradation_overhead(rows, reqs=4 if smoke else 8,
                                    n=1024 if smoke else 4096)
    derived = dict(gates)
    derived.update({
        "fir_snr_db_clean_bbm0": fir["bbm0"][0],
        "fir_snr_db_worst_bbm0": fir["bbm0"][-1],
        "fir_snr_db_clean_booth": fir["booth"][0],
        "fir_snr_db_worst_booth": fir["booth"][-1],
        # resilience headline: how much of the faulted SNR collapse is
        # datapath-specific (bbm vs exact booth at the top fault rate)
        "fir_fault_gap_db": fir["booth"][-1] - fir["bbm0"][-1],
        "matmul_rel_err_worst_bbm0_plane": mm["bbm0_plane"][-1],
        "matmul_rel_err_worst_bbm0_acc": mm["bbm0_acc"][-1],
        "degradation_overhead_x": overhead,
        "cells": len(rows),
    })
    if out:
        config = {
            "smoke": smoke, "fault_rates": FAULT_RATES,
            "specs": [str(s) for s in SPECS],
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "numpy_version": np.__version__,
            "python_version": platform_mod.python_version(),
            "platform": platform_mod.platform(),
            "machine": platform_mod.machine(),
            "cpu_count": os.cpu_count(),
        }
        with open(out, "w") as f:
            json.dump({"config": config, "derived": derived, "rows": rows},
                      f, indent=1)
    return rows, derived


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="reduced configuration for CI")
    p.add_argument("--out", default="BENCH_robustness.json",
                   help="results file")
    args = p.parse_args(argv)
    _, derived = robustness(smoke=args.smoke, out=args.out)
    print(json.dumps(derived, indent=1, sort_keys=True))
    # CI gate: the fault-injection equality contract and the quarantine
    # behaviour must both hold
    return 0 if derived["fault_equality_bitexact"] \
        and derived["poison_ejection"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
