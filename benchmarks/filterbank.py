"""Filterbank benchmark: accumulate-form trajectory + end-to-end serving.

Times the batched multi-channel Broken-Booth FIR datapath through
``dsp.fir_apply`` (quantize -> filterbank -> descale) and derives
throughput in filtered samples/second plus the paper-anchored quality
number (mean SNR_out across channels at the wl=16 operating point), and
the perf trajectory of the datapath across PRs, on the same shapes:

  * kernel: the PR-1 kernel body (Booth digits re-derived from the raw tap
    codes inside every tap of every grid step; reproduced locally here) vs
    the PR-2 precoded rows kernel (digit planes decoded once per bank) vs
    the dot form (exact contraction on the matmul units minus the low-bit
    correction — on CPU the rows kernel runs through the Pallas
    interpreter while the dot form is what the entry point actually
    lowers to: plain compiled XLA; that asymmetry *is* the design, the
    dot form exists to reach the platform matmul instead of emulating
    rows),
  * host: the PR-1 windowed host path vs the PR-2 per-tap
    shift-and-accumulate path vs the dot form,
  * serving: fresh decode-per-flush (PR-1) vs ``FilterbankEngine``'s
    cached ``PrecodedBank`` (PR-2, rows form) vs the engine on the dot
    form.

Every comparison also asserts bit-exactness; a rows-side mismatch shows
up as ``kernel_bitexact: 0`` and a dot-form mismatch as
``dotform_bitexact: 0`` in the derived dict (CI fails on either).
Results are written to ``BENCH_filterbank.json`` with platform/version
metadata in the ``config`` block so trajectories across machines are
interpretable.

On CPU the rows kernel runs through the Pallas interpreter, which is
orders of magnitude slower than compiled TPU code — so the host
closed-form backend is swept densely and the kernels are sampled at the
wl=16 operating point.  On a TPU backend the sweep times the compiled
kernels themselves.
"""
from __future__ import annotations

import functools
import json
import os
import platform as platform_mod
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multipliers import MulSpec, mul
from repro.dsp import PrecodedBank, design_lowpass, fir_apply
from repro.dsp.fir import _amp, _codes32, _descale, _quantize64
from repro.dsp.testbed import run_filterbank_case
from repro.kernels import (booth_precode, fir_bbm_bank_precoded,
                           min_safe_shift, on_tpu)
from repro.kernels.booth_rows import split_signed


def _pr1_rows_product(a_s, bu, *, wl, vbl, kind):
    """The PR-1 row loop, reproduced verbatim as the baseline: Booth digits
    re-derived from the raw code per row, one array op at a time."""
    prod = None
    prev_hi = None
    for r in range(wl // 2):
        b_hi = (bu >> (2 * r + 1)) & 1
        b_mid = (bu >> (2 * r)) & 1
        b_lo = jnp.zeros_like(b_mid) if r == 0 else prev_hi
        prev_hi = b_hi
        d = -2 * b_hi + b_mid + b_lo
        m = max(0, vbl - 2 * r)
        if kind == 0:
            rows = d * a_s
            contrib = (rows >> m) << m
        else:
            mag = jnp.abs(d)
            pos = mag * a_s
            rows = jnp.where(b_hi == 1, -pos - 1, pos)
            contrib = (rows >> m) << m
            if m == 0:
                contrib = contrib + b_hi
        term = contrib << (2 * r)
        prod = term if prod is None else prod + term
    return prod

# (channels, signal length) grid; wl -> paper-ish operating vbl
SHAPES = [(4, 1 << 11), (8, 1 << 12), (16, 1 << 12)]
POINTS = [(8, 5), (12, 9), (16, 13)]
# reduced configuration for the CI smoke step
SMOKE_SHAPES = [(4, 1 << 10)]
SMOKE_POINTS = [(16, 13)]


def _time(fn, repeats: int = 3) -> float:
    """Median wall time — robust to scheduler noise on shared CPU runners."""
    return _time_many([fn], repeats)[0]


def _time_many(fns, repeats: int = 3) -> list[float]:
    """Median wall times of several candidates, measured round-robin.

    Cells that are compared against each other (rows vs dot form, legacy
    vs precoded) must not be timed in separate back-to-back batches: on a
    shared 2-core runner the load drifts on the scale of one batch, and a
    sequential A-then-B measurement hands whichever ran in the quieter
    window a phantom speedup.  Interleaving the rounds makes every
    candidate sample the same noise distribution.
    """
    for fn in fns:
        fn()                               # warm-up / compile
    ts = [[] for _ in fns]
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            ts[i].append(time.perf_counter() - t0)
    return [float(np.median(t)) for t in ts]


# ----------------------------------------------------- PR-1 kernel baseline
def _legacy_fir_kernel(x_ref, h_ref, o_ref, halo_ref, *, wl, vbl, kind,
                       taps, shift, bt):
    """The PR-1 kernel body: recode inside the hot loop (baseline only)."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _zero_state():
        halo_ref[...] = jnp.zeros_like(halo_ref)

    xs = jnp.concatenate([halo_ref[...], x_ref[...]], axis=1)
    h = h_ref[...]
    mask = (1 << wl) - 1
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for k in range(taps):
        _, a_s = split_signed(xs[:, taps - 1 - k:taps - 1 - k + bt], wl)
        bu = (h[:, k] & mask)[:, None]
        # digits re-derived from the raw code for every tap of every step
        prod = _pr1_rows_product(a_s, bu, wl=wl, vbl=vbl, kind=kind)
        if shift:
            prod = prod >> shift
        acc = acc + prod
    o_ref[...] = acc
    halo_ref[...] = xs[:, bt:]


@functools.partial(jax.jit, static_argnames=("wl", "vbl", "kind", "shift",
                                             "bc", "bt", "interpret"))
def _legacy_fir_bank(x, h, *, wl, vbl, kind=0, shift=0, bc=8, bt=512,
                     interpret=False):
    channels, n = x.shape
    taps = h.shape[1]
    bc = min(bc, channels)
    bt = min(bt, n)
    nc = pl.cdiv(channels, bc)
    nt = pl.cdiv(n, bt)
    xp = jnp.pad(x, ((0, nc * bc - channels), (0, nt * bt - n)))
    hp = jnp.pad(h, ((0, nc * bc - channels), (0, 0)))
    kernel = functools.partial(_legacy_fir_kernel, wl=wl, vbl=vbl, kind=kind,
                               taps=taps, shift=shift, bt=bt)
    out = pl.pallas_call(
        kernel,
        grid=(nc, nt),
        in_specs=[
            pl.BlockSpec((bc, bt), lambda c, t: (c, t)),
            pl.BlockSpec((bc, taps), lambda c, t: (c, 0)),
        ],
        out_specs=pl.BlockSpec((bc, bt), lambda c, t: (c, t)),
        out_shape=jax.ShapeDtypeStruct((nc * bc, nt * bt), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bc, taps - 1), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xp, hp)
    return out[:channels, :n]


# ------------------------------------------------------- PR-1 host baseline
def _legacy_host_windowed(x, h, spec, shift):
    """The PR-1 host path: (C, N, taps) gathered window (baseline only)."""
    amp = _amp(x)
    xq = _quantize64(x * amp, spec.wl)
    hq = _quantize64(h, spec.wl)
    n = xq.shape[-1]
    taps = hq.shape[-1]
    idx = np.arange(n)[:, None] - np.arange(taps)[None, :]
    win = np.where(idx >= 0, xq[..., np.clip(idx, 0, None)], 0)
    prod = np.asarray(mul(spec)(jnp.asarray(_codes32(win, spec.wl)),
                                jnp.asarray(_codes32(hq, spec.wl))[:, None, :]),
                      np.int64)
    if shift:
        prod = prod >> shift
    return _descale(prod.astype(np.float64).sum(axis=-1), spec.wl, shift, amp)


# --------------------------------------------------------------- the sweep
def _kernel_micro(channels, n, wl, vbl, interpret, rows):
    """Kernel trajectory: legacy body vs precoded rows vs dot form.

    -> (speedup_precoded, speedup_dotform, ok_rows, ok_dot).  The rows
    cells run the kernel exactly as the entry point does on this backend
    (interpreted off-TPU); the dot cell runs what ``form=None`` resolves
    to — compiled XLA on the platform matmul — so ``kernel_speedup_dotform``
    is the measured win of the new auto-picked path over the PR-2 one.
    """
    rng = np.random.default_rng(2)
    shift = min_safe_shift(31, wl)
    x = jnp.asarray(rng.integers(0, 1 << wl, (channels, n)), jnp.int32)
    h = jnp.asarray(rng.integers(0, 1 << wl, (channels, 31)), jnp.int32)
    kw = dict(wl=wl, vbl=vbl, kind=0, shift=shift, bc=min(channels, 8),
              bt=min(n, 512), interpret=interpret)
    hmag, hneg = booth_precode(h, wl)
    t_leg, t_pre, t_dot = _time_many(
        [lambda: jax.block_until_ready(_legacy_fir_bank(x, h, **kw)),
         lambda: jax.block_until_ready(
             fir_bbm_bank_precoded(x, hmag, hneg, form="rows", **kw)),
         lambda: jax.block_until_ready(
             fir_bbm_bank_precoded(x, hmag, hneg, form="dot", **kw))],
        repeats=15)
    ref = np.asarray(_legacy_fir_bank(x, h, **kw))
    ok_rows = bool(np.array_equal(ref, np.asarray(
        fir_bbm_bank_precoded(x, hmag, hneg, form="rows", **kw))))
    ok_dot = bool(np.array_equal(ref, np.asarray(
        fir_bbm_bank_precoded(x, hmag, hneg, form="dot", **kw))))
    rows.append({"cell": "kernel_raw_recode", "channels": channels, "n": n,
                 "wl": wl, "vbl": vbl, "us_per_call": t_leg * 1e6})
    rows.append({"cell": "kernel_precoded", "channels": channels, "n": n,
                 "wl": wl, "vbl": vbl, "us_per_call": t_pre * 1e6})
    rows.append({"cell": "kernel_dotform", "channels": channels, "n": n,
                 "wl": wl, "vbl": vbl, "us_per_call": t_dot * 1e6})
    return t_leg / t_pre, t_pre / t_dot, ok_rows, ok_dot


def _host_micro(channels, n, wl, vbl, rows):
    """Host trajectory: windowed (PR-1) vs per-tap (PR-2) vs dot form.

    -> (speedup_per_tap, speedup_dotform, ok_rows, ok_dot).  All three
    are compiled
    host datapaths on the same signals; the dot cell measures the
    identity rewrite alone (same backend, same pipeline).
    """
    rng = np.random.default_rng(3)
    spec = MulSpec("bbm0", wl, vbl)
    shift = min_safe_shift(31, wl)
    x = rng.standard_normal((channels, n))
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    h = banks[np.arange(channels) % 2]
    t_win, t_tap, t_dot = _time_many(
        [lambda: _legacy_host_windowed(x, h, spec, shift),
         lambda: fir_apply(x, h, spec, backend="host", shift=shift,
                           form="rows"),
         lambda: fir_apply(x, h, spec, backend="host", shift=shift,
                           form="dot")], repeats=9)
    ref = _legacy_host_windowed(x, h, spec, shift)
    ok = bool(np.array_equal(ref, fir_apply(x, h, spec, backend="host",
                                            shift=shift, form="rows")))
    ok_dot = bool(np.array_equal(ref, fir_apply(x, h, spec, backend="host",
                                                shift=shift, form="dot")))
    rows.append({"cell": "host_windowed", "channels": channels, "n": n,
                 "wl": wl, "vbl": vbl, "us_per_call": t_win * 1e6})
    rows.append({"cell": "host_per_tap", "channels": channels, "n": n,
                 "wl": wl, "vbl": vbl, "us_per_call": t_tap * 1e6})
    rows.append({"cell": "host_dotform", "channels": channels, "n": n,
                 "wl": wl, "vbl": vbl, "us_per_call": t_dot * 1e6})
    return t_win / t_tap, t_tap / t_dot, ok, ok_dot


def _engine_micro(wl, vbl, n_req, n_samp, block, backend, rows):
    """Serving trajectory: fresh decode vs cached rows vs cached dot form.

    -> (speedup_cached, speedup_dotform, ok_rows, ok_dot, rate).
    ``speedup_cached``
    keeps the PR-2 meaning (fresh-vs-cached, rows form on both sides);
    ``speedup_dotform`` is cached-rows vs cached-dot on the same engine
    configuration, and ``rate`` reports the best serving throughput.
    """
    from repro.serve import FilterbankEngine
    rng = np.random.default_rng(4)
    spec = MulSpec("bbm0", wl, vbl)
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    sigs = [rng.standard_normal(n_samp) for _ in range(n_req)]
    engines = {form: FilterbankEngine(banks, spec, backend=backend,
                                      max_channels=n_req, block=block,
                                      form=form)
               for form in ("rows", "dot")}

    def cached_round(form):
        eng = engines[form]
        for i, s in enumerate(sigs):
            eng.submit(s, bank=i % 2)
        return eng.flush()

    x = np.stack(sigs)
    h = banks[np.arange(n_req) % 2]

    def fresh_round():
        # PR-1 per-flush behaviour: quantize + recode the banks every time
        return fir_apply(x, h, spec, backend=backend, block=block,
                         form="rows")

    t_cached, t_dot, t_fresh = _time_many(
        [lambda: cached_round("rows"), lambda: cached_round("dot"),
         fresh_round], repeats=15)
    ref = fresh_round()
    out = cached_round("rows")             # rids ascend in submit order
    out_dot = cached_round("dot")
    ok = bool(np.array_equal(np.stack([out[r] for r in sorted(out)]), ref))
    ok_dot = bool(np.array_equal(
        np.stack([out_dot[r] for r in sorted(out_dot)]), ref))
    rate = n_req * n_samp / min(t_cached, t_dot)
    rows.append({"cell": "engine_fresh_bank", "channels": n_req, "n": n_samp,
                 "wl": wl, "vbl": vbl, "backend": backend,
                 "us_per_call": t_fresh * 1e6})
    rows.append({"cell": "engine_cached_bank", "channels": n_req,
                 "n": n_samp, "wl": wl, "vbl": vbl, "backend": backend,
                 "us_per_call": t_cached * 1e6,
                 "samples_per_s": n_req * n_samp / t_cached})
    rows.append({"cell": "engine_dotform", "channels": n_req,
                 "n": n_samp, "wl": wl, "vbl": vbl, "backend": backend,
                 "us_per_call": t_dot * 1e6,
                 "samples_per_s": n_req * n_samp / t_dot})
    return t_fresh / t_cached, t_cached / t_dot, ok, ok_dot, rate


def filterbank_sweep(smoke: bool = False, out: str | None = None):
    rng = np.random.default_rng(0)
    shapes = SMOKE_SHAPES if smoke else SHAPES
    points = SMOKE_POINTS if smoke else POINTS
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    # timed sweep: what the entry point runs on this backend (the dot
    # form off-TPU); the bit-exactness checkpoint pins form="rows" on the
    # kernel side so the Pallas rows pipeline is cross-checked against the
    # auto datapath on every sweep shape
    backend = "pallas" if on_tpu() else "host"
    check_backend = "pallas" if on_tpu() else "pallas-interpret"
    rows = []
    best_rate = 0.0
    bitexact = True
    for channels, n in shapes:
        x = rng.standard_normal((channels, n))
        h = banks[np.arange(channels) % 2]
        for wl, vbl in points:
            spec = MulSpec("bbm0", wl, vbl)
            dt = _time(lambda: fir_apply(x, h, spec, backend=backend))
            rate = channels * n / dt
            best_rate = max(best_rate, rate)
            rows.append({"cell": "sweep", "channels": channels, "n": n,
                         "wl": wl, "vbl": vbl, "backend": backend,
                         "us_per_call": dt * 1e6, "samples_per_s": rate})
        # one kernel cell per shape: bit-exactness checkpoint vs host
        wl, vbl = points[-1]
        spec = MulSpec("bbm0", wl, vbl)
        shift = min_safe_shift(h.shape[1], wl)
        a = fir_apply(x, h, spec, backend="host", shift=shift)
        b = fir_apply(x, h, spec, backend=check_backend, shift=shift,
                      form="rows")
        bitexact &= bool(np.array_equal(a, b))

    # accumulate-form micro-benchmarks at the wl=16 operating point.  The
    # kernel and engine cells run at serving-representative block sizes
    # (a couple of thousand samples per dispatch): the decode phase is a
    # fixed per-call cost, so giant signals would amortize away exactly
    # the overhead the precoded path removes.
    wl, vbl = 16, 13
    k_speed, k_dot_speed, k_ok, k_dot_ok = _kernel_micro(
        4, 1 << 11, wl, vbl, not on_tpu(), rows)
    h_speed, h_dot_speed, h_ok, h_dot_ok = _host_micro(
        *((4, 1 << 10) if smoke else (8, 1 << 12)), wl, vbl, rows)
    e_req, e_samp = (3, 512) if smoke else (8, 512)
    e_speed, e_dot_speed, e_ok, e_dot_ok, e_rate = _engine_micro(
        wl, vbl, e_req, e_samp, min(512, e_samp), check_backend, rows)
    bitexact &= k_ok and h_ok and e_ok
    dot_bitexact = k_dot_ok and h_dot_ok and e_dot_ok

    derived = {
        "best_samples_per_s": best_rate,
        "kernel_bitexact": int(bitexact),
        "dotform_bitexact": int(dot_bitexact),
        "kernel_speedup_precoded": k_speed,
        "kernel_speedup_dotform": k_dot_speed,
        "host_speedup_per_tap": h_speed,
        "host_speedup_dotform": h_dot_speed,
        "engine_speedup_cached_bank": e_speed,
        "engine_speedup_dotform": e_dot_speed,
        "engine_samples_per_s": e_rate,
        "cells": len(rows),
    }
    if not smoke:
        snrs = run_filterbank_case(MulSpec("bbm0", 16, 13), channels=4,
                                   n=1 << 12)
        derived["mean_snr_db_wl16_vbl13"] = float(np.mean(snrs))
    if out:
        config = {
            "smoke": smoke, "backend": backend, "on_tpu": on_tpu(),
            # platform metadata: bench trajectories are only comparable
            # within one (machine, backend, jax) triple
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "numpy_version": np.__version__,
            "python_version": platform_mod.python_version(),
            "platform": platform_mod.platform(),
            "machine": platform_mod.machine(),
            "cpu_count": os.cpu_count(),
        }
        with open(out, "w") as f:
            json.dump({"config": config, "derived": derived, "rows": rows},
                      f, indent=1)
    return rows, derived


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="reduced configuration for CI")
    p.add_argument("--out", default="BENCH_filterbank.json",
                   help="results file (the sweep only writes one when "
                        "invoked through this entry point)")
    args = p.parse_args(argv)
    _, derived = filterbank_sweep(smoke=args.smoke, out=args.out)
    print(json.dumps(derived, indent=1, sort_keys=True))
    # CI gate: both the rows pipeline and the dot form must be bit-exact
    return 0 if derived["kernel_bitexact"] and derived["dotform_bitexact"] \
        else 1


if __name__ == "__main__":
    raise SystemExit(main())
