"""Filterbank benchmark: channels x signal length x (wl, vbl) sweep.

Times the batched multi-channel Broken-Booth FIR datapath end to end
(quantize -> filterbank -> descale) through ``dsp.fir_apply`` and derives
throughput in filtered samples/second plus the paper-anchored quality
number (mean SNR_out across channels at the wl=16 operating point).

On CPU the kernel runs through the Pallas interpreter, which is orders of
magnitude slower than compiled TPU code — so the host closed-form backend
is swept densely and the interpreted kernel is sampled once per shape at
the wl=16 operating point purely as a bit-exactness checkpoint (mismatch shows up as
``kernel_bitexact: 0`` in the derived dict).  On a TPU backend the sweep
times the compiled kernel itself.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.multipliers import MulSpec
from repro.dsp import fir_apply, design_lowpass
from repro.dsp.testbed import make_filterbank_signals, run_filterbank_case
from repro.kernels import min_safe_shift, on_tpu

# (channels, signal length) grid; wl -> paper-ish operating vbl
SHAPES = [(4, 1 << 11), (8, 1 << 12), (16, 1 << 12)]
POINTS = [(8, 5), (12, 9), (16, 13)]


def _time(fn, repeats: int = 3) -> float:
    fn()                                   # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def filterbank_sweep():
    rng = np.random.default_rng(0)
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    # timed sweep: compiled kernel on TPU, closed forms on host; the
    # bit-exactness checkpoint always goes through the kernel (interpreted
    # off-TPU)
    backend = "pallas" if on_tpu() else "host"
    check_backend = "pallas" if on_tpu() else "pallas-interpret"
    rows = []
    best_rate = 0.0
    kernel_bitexact = True
    for channels, n in SHAPES:
        x = rng.standard_normal((channels, n))
        h = banks[np.arange(channels) % 2]
        for wl, vbl in POINTS:
            spec = MulSpec("bbm0", wl, vbl)
            dt = _time(lambda: fir_apply(x, h, spec, backend=backend))
            rate = channels * n / dt
            best_rate = max(best_rate, rate)
            rows.append({"channels": channels, "n": n, "wl": wl, "vbl": vbl,
                         "backend": backend, "us_per_call": dt * 1e6,
                         "samples_per_s": rate})
        # one kernel cell per shape: bit-exactness checkpoint vs host
        wl, vbl = POINTS[-1]
        spec = MulSpec("bbm0", wl, vbl)
        shift = min_safe_shift(h.shape[1], wl)
        a = fir_apply(x, h, spec, backend="host", shift=shift)
        b = fir_apply(x, h, spec, backend=check_backend, shift=shift)
        kernel_bitexact &= bool(np.array_equal(a, b))
    snrs = run_filterbank_case(MulSpec("bbm0", 16, 13), channels=4,
                               n=1 << 12)
    derived = {
        "best_samples_per_s": best_rate,
        "mean_snr_db_wl16_vbl13": float(np.mean(snrs)),
        "kernel_bitexact": int(kernel_bitexact),
        "cells": len(rows),
    }
    return rows, derived
