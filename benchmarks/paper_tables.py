"""One function per paper table/figure.  Each returns (rows, derived) where
rows is a list of dicts (the table) and derived a dict of headline numbers
compared against the paper's claims."""
from __future__ import annotations

import numpy as np

from repro.core import MulSpec, characterize, error_histogram
from repro.core.hwmodel import (PAPER_AREA_REDUCTION, PAPER_POWER_REDUCTION,
                                PAPER_TABLE4, area, fir_area, fir_power,
                                pdp_avg, power, power_at, quap, tmin)
from repro.dsp import (FIR_DELAY, design_lowpass, fir_apply_fixed,
                       make_signals, run_filter_case, snr_db)

PAPER_TABLE1 = {
    3: (-3.50, 2.22e1, 0.6875, -1.10e1),
    6: (-6.15e1, 5.05e3, 0.9375, -1.71e2),
    9: (-7.89e2, 7.52e5, 0.9893, -2.22e3),
    12: (-8.53e3, 8.33e7, 0.9983, -2.32e4),
}


def table1_errstats():
    """Table I: exhaustive error stats of Broken-Booth Type0, WL=12."""
    rows = []
    max_rel = 0.0
    for vbl, (pm, pmse, pprob, pmin) in PAPER_TABLE1.items():
        st = characterize(MulSpec("bbm0", 12, vbl))
        rows.append({"vbl": vbl, "mean": st.mean, "mse": st.mse,
                     "prob": st.prob, "min": st.min,
                     "paper_mean": pm, "paper_mse": pmse,
                     "paper_prob": pprob, "paper_min": pmin})
        max_rel = max(max_rel, abs(st.mse - pmse) / pmse,
                      abs(st.mean - pm) / abs(pm))
    return rows, {"max_rel_delta_vs_paper": max_rel, "n_vectors": 1 << 24}


def fig2_histogram():
    """Fig 2: error distribution, WL=10, VBL=9 (normalized to 2^19)."""
    centers, pct = error_histogram(MulSpec("bbm0", 10, 9), bins=41)
    mass_neg = float(pct[centers < 0].sum())
    nonzero_bins = int((pct > 0.1).sum())
    return ([{"center": float(c), "pct": float(p)}
             for c, p in zip(centers, pct) if p > 0],
            {"negative_mass_pct": mass_neg, "resolved_bins": nonzero_bins})


def table2_3_power_area():
    """Tables II/III: modeled power/area reduction vs the paper's means."""
    rows = []
    deltas = []
    for wl in (4, 8, 12, 16):
        p0, p1 = power(MulSpec("bbm0", wl, 0)), power(MulSpec("bbm0", wl, wl - 1))
        a0, a1 = area(MulSpec("bbm0", wl, 0)), area(MulSpec("bbm0", wl, wl - 1))
        pr, ar = 100 * (1 - p1 / p0), 100 * (1 - a1 / a0)
        rows.append({"wl": wl, "vbl": wl - 1,
                     "power_red_model": pr,
                     "power_red_paper": PAPER_POWER_REDUCTION[wl],
                     "area_red_model": ar,
                     "area_red_paper": PAPER_AREA_REDUCTION[wl]})
        deltas += [abs(pr - PAPER_POWER_REDUCTION[wl]),
                   abs(ar - PAPER_AREA_REDUCTION[wl])]
    return rows, {"mean_abs_delta_pp": float(np.mean(deltas))}


def fig3_power_delay():
    """Fig 3: power vs delay constraint, accurate vs approximate, WL=16."""
    acc, app = MulSpec("booth", 16, 0), MulSpec("bbm0", 16, 15)
    t_acc, t_app = tmin(acc), tmin(app)
    rows = []
    for mult in (1.0, 1.25, 1.5, 1.75, 2.0):
        t = t_acc * mult
        rows.append({"delay_ns": t,
                     "power_accurate": power_at(acc, t),
                     "power_approx": power_at(app, t)})
    ratio = np.mean([r["power_approx"] / r["power_accurate"] for r in rows])
    return rows, {"tmin_accurate_ns": t_acc, "tmin_approx_ns": t_app,
                  "speedup_pct": 100 * (1 - t_app / t_acc),
                  "paper_speedup_pct": 6.6,
                  "mean_power_ratio": float(ratio)}


def fig56_pdp_mse(wl: int = 12):
    """Figs 5/6: average PDP vs MSE for the four studied multipliers."""
    sweeps = {
        "bbm0": [MulSpec("bbm0", wl, v) for v in (1, 3, 5, 7, 9, 11)],
        "bbm1": [MulSpec("bbm1", wl, v) for v in (1, 3, 5, 7, 9, 11)],
        "bam": [MulSpec("bam", wl, v) for v in (3, 6, 9, 12, 15)],
        "kulkarni": [MulSpec("kulkarni", wl, k) for k in (5, 9, 13, 17, 21)],
        # beyond-paper comparand: ETM (the paper's ref [5], not synthesized
        # there) on the same PDP-vs-MSE axes
        "etm": [MulSpec("etm", wl, sp) for sp in (3, 5, 7, 9)],
    }
    rows = []
    for name, specs in sweeps.items():
        for sp in specs:
            st = characterize(sp, exhaustive=False, sample=1 << 18)
            rows.append({"mul": name, "param": sp.param,
                         "mse": st.mse, "pdp": pdp_avg(sp)})
    # paper claims: kulkarni best at low MSE but flat; booth-family falls
    # steadily; type0 more graceful than type1
    by = lambda n: sorted([r for r in rows if r["mul"] == n],
                          key=lambda r: r["param"])
    kul = by("kulkarni")
    b0 = by("bbm0")
    derived = {
        "kulkarni_pdp_span": kul[0]["pdp"] / kul[-1]["pdp"],
        "bbm0_pdp_span": b0[0]["pdp"] / b0[-1]["pdp"],
        "bbm0_beats_kulkarni_at_high_mse":
            bool(b0[-1]["pdp"] < kul[-1]["pdp"]),
    }
    return rows, derived


def fig8_snr():
    """Fig 8: SNR vs WL (wl-bit datapath) and SNR vs VBL (WL=16)."""
    sig = make_signals()
    h = design_lowpass()
    rows = []
    for wl in (8, 10, 12, 14, 16, 18, 20):
        y = fir_apply_fixed(sig.x, h, MulSpec("booth", wl, 0),
                            datapath="wlbit")
        rows.append({"sweep": "wl", "x": wl,
                     "snr_db": snr_db(sig.d1, y, FIR_DELAY)})
    for vbl in (0, 3, 5, 7, 9, 11, 13, 15, 17, 19):
        y = fir_apply_fixed(sig.x, h, MulSpec("bbm0", 16, vbl))
        rows.append({"sweep": "vbl", "x": vbl,
                     "snr_db": snr_db(sig.d1, y, FIR_DELAY)})
    dbl = run_filter_case(None, sig)
    vbl_rows = [r for r in rows if r["sweep"] == "vbl"]
    op = max((r for r in vbl_rows if r["snr_db"] >= dbl - 0.45),
             key=lambda r: r["x"])
    return rows, {"snr_double_db": dbl, "paper_snr_double_db": 25.7,
                  "operating_vbl_0p4dB": op["x"], "paper_operating_vbl": 13,
                  "snr_at_operating": op["snr_db"]}


def table4_filter():
    """Table IV: the three synthesis cases + QUAP (model power/area, our
    measured SNRs)."""
    sig = make_signals()
    cases = [("WL=16,VBL=0", 16, 0), ("WL=16,VBL=13", 16, 13),
             ("WL=16,VBL=15", 16, 15), ("WL=14,VBL=0", 14, 0)]
    rows = []
    for label, wl, vbl in cases:
        spec = MulSpec("booth" if vbl == 0 else "bbm0", wl, vbl)
        snr = run_filter_case(spec, sig)
        rows.append({"case": label, "snr_db": snr,
                     "power_mw": fir_power(wl, vbl),
                     "area_um2": fir_area(wl, vbl)})
    base = rows[0]
    for r in rows[1:]:
        pwr_sav = 100 * (1 - r["power_mw"] / base["power_mw"])
        area_sav = 100 * (1 - r["area_um2"] / base["area_um2"])
        r["power_saving_pct"] = pwr_sav
        r["quap"] = quap(r["snr_db"], max(area_sav, 0.0), max(pwr_sav, 0.0))
    paper_snr = {k: v[0] for k, v in PAPER_TABLE4.items()}
    derived = {
        "power_red_vbl13_pct": rows[1]["power_saving_pct"],
        "paper_power_red_pct": 17.1,
        "snr_loss_vbl13_db": rows[0]["snr_db"] - rows[1]["snr_db"],
        "paper_snr_loss_db": 0.35,
        "quap_vbl13_over_wl14":
            rows[1]["quap"] / max(rows[3].get("quap", 1e-9), 1e-9),
        "paper_quap_ratio": 13.1 / 7.73,
    }
    return rows, derived
