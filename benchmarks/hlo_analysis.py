"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` visits each while-loop body ONCE, so a
model built on ``lax.scan`` (layers, microbatches, attention KV blocks, SSD
chunks) under-reports FLOPs/bytes/collectives by the product of trip counts.
This module re-derives costs from the post-optimization HLO text:

  1. split the module into computations,
  2. find every `while` op, link its condition/body computations, and read
     the static trip count out of the condition's `compare(iv, constant(N))`
     (falling back to known config trip counts when the pattern is dynamic),
  3. propagate multipliers through the call graph (nested scans multiply),
  4. per computation, accumulate
       * dot/convolution FLOPs from shapes + dot_dimension_numbers
         (matmul-dominated models: elementwise flops are ignored, which
         under-counts by <2% on these architectures),
       * result-buffer bytes of every op (x2 as a read+write bandwidth
         proxy; documented accuracy +-2x, used for the memory term),
       * collective wire bytes with ring-cost factors.

The result is the per-device cost of one full step, derived entirely from
the compiled artifact.
"""
from __future__ import annotations

import gzip
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COMP_RE = re.compile(r"^(?:%(\S+)|(\S+))\s*\(.*\)\s*->.*\{\s*$")
_ENTRY_RE = re.compile(r"^ENTRY\s+(?:%)?(\S+?)\s*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=(?:%)?([\w.\-]+).*?body=(?:%)?([\w.\-]+)")
_CALL_RE = re.compile(
    r"(?:to_apply|calls)=(?:%)?([\w.\-]+)")
_FUSION_RE = re.compile(r"fusion\(.*?\).*?calls=(?:%)?([\w.\-]+)")
_CONST_CMP = re.compile(r"constant\((\d+)\)")
_DOT_RE = re.compile(r"\bdot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]+)\}")
_LHS_SHAPE_RE = re.compile(r"dot\((?:%)?[\w.\-]+\s*,")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_GROUP_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUP_V2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _parse_shape(text: str) -> Tuple[int, int]:
    """(total bytes, elem count) of the FIRST shape literal in text."""
    m = _SHAPE_RE.search(text)
    if not m:
        return 0, 0
    dt, dims = m.groups()
    n = int(np.prod([int(x) for x in dims.split(",") if x] or [1]))
    return _DTYPE_BYTES.get(dt, 4) * n, n


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    depth = 0
    for line in hlo.splitlines():
        ls = line.strip()
        if cur is None:
            m = re.match(r"^(ENTRY\s+)?(?:%)?([\w.\-]+)\s*\(.*\)\s*->.*{", ls)
            if m and ("->" in ls):
                cur = m.group(2)
                comps[cur] = []
                depth = 1
                continue
        else:
            depth += ls.count("{") - ls.count("}")
            if depth <= 0:
                cur = None
                continue
            comps[cur].append(ls)
    return comps


def find_entry(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+(?:%)?([\w.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def trip_count(cond_lines: List[str]) -> Optional[int]:
    """Static trip bound from the condition computation.

    Matches `compare(iv, constant(N)) direction=LT` shapes; returns N.
    """
    consts = {}
    for ln in cond_lines:
        m = re.match(r"(?:%)?([\w.\-]+)\s*=\s*\S+\s+constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if "compare(" not in ln:
            continue
        m = re.search(r"compare\((?:%)?([\w.\-]+),\s*(?:%)?([\w.\-]+)\)", ln)
        dirm = re.search(r"direction=(\w+)", ln)
        if not m:
            continue
        a, b = m.groups()
        d = dirm.group(1) if dirm else "LT"
        if b in consts and d == "LT":
            return consts[b]
        if a in consts and d == "GT":
            return consts[a]
        inline = _CONST_CMP.search(ln)
        if inline:
            return int(inline.group(1))
    return None


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
# dot operands print either bare (`dot(%a, %b)`) or typed
# (`dot(f32[64,64]{1,0} %a, ...)`) depending on the XLA build; accept both
# and capture the inline operand shape when present so contracted dims can
# be read straight off the line without a symbol-table hit.
_OPERAND = (r"(?:[a-z0-9]+\[(?P<{s}>[0-9,]*)\](?:\{{[^}}]*\}})?\s+)?"
            r"%?(?P<{n}>[\w.\-]+)")
_DOT_ARGS = re.compile(r"dot\(\s*" + _OPERAND.format(s="lshape", n="lhs")
                       + r"\s*,\s*" + _OPERAND.format(s="rshape", n="rhs")
                       + r"\s*\)")
_RHS_CONTRACT_RE = re.compile(r"rhs_contracting_dims=\{([0-9,]+)\}")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _build_symtab(lines: List[str]) -> Dict[str, List[int]]:
    """opname -> result dims for every op defined in a computation."""
    sym: Dict[str, List[int]] = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            name, _, dims = m.groups()
            sym[name] = [int(x) for x in dims.split(",") if x]
    return sym


def _dot_flops(line: str, sym: Dict[str, List[int]]) -> float:
    """2 * prod(result dims) * prod(contracted dims).

    The 2x multiply-add convention matches ``model_flops``'s 6ND-style
    accounting; operand dims come from the inline typed operand when the
    build prints one, falling back to the computation's symbol table.
    """
    first = _SHAPE_RE.search(line)       # result shape is leftmost
    if not first:
        return 0.0
    res_dims = [int(x) for x in first.group(2).split(",") if x]
    contracted = 1
    args = _DOT_ARGS.search(line)
    lhs_c = _CONTRACT_RE.search(line)
    rhs_c = _RHS_CONTRACT_RE.search(line)
    if args:
        def dims_of(shape_group, name_group):
            inline = args.group(shape_group)
            if inline is not None:
                return [int(x) for x in inline.split(",") if x]
            return sym.get(args.group(name_group))

        for contract_re, shape_g, name_g in (
                (lhs_c, "lshape", "lhs"), (rhs_c, "rshape", "rhs")):
            dims = dims_of(shape_g, name_g)
            if contract_re and dims is not None:
                for idx in (int(i) for i in contract_re.group(1).split(",")
                            if i):
                    if idx < len(dims):
                        contracted *= dims[idx]
                break
    return 2.0 * float(np.prod(res_dims or [1])) * contracted


def _collective_wire(line: str, op: str) -> float:
    nbytes, _ = _parse_shape(line)
    n = 1
    g = _GROUP_RE.search(line)
    if g:
        n = max(len(g.group(1).split(",")), 1)
    else:
        g2 = _GROUP_V2.search(line)
        if g2:
            n = int(g2.group(2))
    if n <= 1:
        return 0.0
    ring = (n - 1) / n
    if op == "all-gather":
        return nbytes * ring
    if op == "reduce-scatter":
        return nbytes * (n - 1)
    if op == "all-reduce":
        return 2 * nbytes * ring
    if op == "all-to-all":
        return nbytes * ring
    return float(nbytes)        # collective-permute


def analyze_hlo(hlo: str, known_trips: Optional[Dict[str, int]] = None
                ) -> Dict[str, float]:
    """Trip-count-corrected per-device costs of the whole module."""
    comps = split_computations(hlo)
    entry = find_entry(hlo)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}

    # per-computation local costs + call edges
    local = {}
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for name, lines in comps.items():
        sym = _build_symtab(lines)
        fl = by = co = 0.0
        ed: List[Tuple[str, float]] = []
        for ln in lines:
            # zero-cost ops: aliases, metadata, layout changes — no HBM
            if (" get-tuple-element(" in ln or " tuple(" in ln
                    or " bitcast(" in ln or " parameter(" in ln
                    or ln.startswith("ROOT %tuple")
                    or " after-all(" in ln or " constant(" in ln):
                pass
            else:
                b, _ = _parse_shape(ln)
                by += 2.0 * b                   # write + ~read proxy
            if " dot(" in ln:
                fl += _dot_flops(ln, sym)
            elif "convolution(" in ln:
                fl += _dot_flops(ln, sym)       # same shape heuristic
            for op in _COLL_OPS:
                if f" {op}(" in ln or f"{op}-start(" in ln:
                    co += _collective_wire(ln, op)
                    break
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.groups()
                tc = _TRIP_CFG.search(ln)       # XLA's own annotation first
                t = int(tc.group(1)) if tc else trip_count(
                    comps.get(cond, []))
                if t is None and known_trips:
                    t = known_trips.get(body, 1)
                ed.append((body, float(t or 1), "while"))
                continue
            fm = _FUSION_RE.search(ln)
            if fm:
                # fusion internals: real flops/collectives, but the
                # intermediates live in registers/VMEM — no HBM bytes
                ed.append((fm.group(1), 1.0, "fusion"))
                continue
            cm2 = _CALL_RE.search(ln)
            if cm2 and ("reduce(" in ln or "call(" in ln or "map(" in ln
                        or "scatter(" in ln or "select-and-scatter(" in ln
                        or "sort(" in ln or "custom-call(" in ln):
                ed.append((cm2.group(1), 1.0, "call"))
        local[name] = (fl, by, co)
        edges[name] = ed

    # propagate with memoized DFS (call graph is a DAG in HLO)
    memo: Dict[str, Tuple[float, float, float]] = {}

    def total(name: str, depth=0) -> Tuple[float, float, float]:
        if name in memo:
            return memo[name]
        if name not in local or depth > 64:
            return (0.0, 0.0, 0.0)
        fl, by, co = local[name]
        for child, mult, kind in edges.get(name, []):
            cf, cb, cc = total(child, depth + 1)
            fl += mult * cf
            if kind == "while":     # fusion/apply bodies: no HBM traffic
                by += mult * cb
            co += mult * cc
        memo[name] = (fl, by, co)
        return memo[name]

    fl, by, co = total(entry)
    return {"flops": fl, "bytes": by, "collective_bytes": co}


def analyze_file(path: str, **kw) -> Dict[str, float]:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rt") as f:
        return analyze_hlo(f.read(), **kw)
