"""Attention benchmark: flash-amm vs chunked-amm vs exact-flash.

Times causal self-attention throughput (tokens/s) against context length
for the three routes ``models.attention.attention`` can take when
``use_pallas`` is set:

  * exact_flash: the exact flash kernel (``kernels.flash_attention``) —
    on CPU this runs through the Pallas interpreter, so its absolute
    numbers are context only (the cell is what a TPU backend compiles),
  * chunked_amm: the Broken-Booth datapath on the PR-5 chunked
    online-softmax schedule at the model-default tiles (bq=512/bk=1024),
    s32 dot-form contractions — the pre-flash fallback and the bitwise
    reference,
  * flash_amm: the same datapath on the flash schedule
    (``kernels.flash_attention_amm``) — per-tile quantization at
    128/128 tiles with the correction contractions lowered onto
    f32-exact-envelope gemms.  Off TPU the fused XLA lowering of the
    tile step is timed (that is what the route runs); on TPU the Pallas
    kernel itself.

Cells that are compared are timed round-robin (interleaved rounds, same
noise distribution — see benchmarks/filterbank.py for the rationale) and
reported as median us_per_call plus tokens/s.  Derived metrics:

  * ``flash_amm_bitexact``: flash-amm output == chunked-amm output via
    ``assert_array_equal`` at matched tiles and head counts
    (``models.attention.flash_amm_chunked_equiv``) — quantization is per
    block, so this is an exact-integer contract, not an allclose one.
    CI fails on 0.
  * ``flash_amm_speedup``: chunked-amm time / flash-amm time at the
    largest context swept.

Results land in ``BENCH_attention.json`` with platform metadata in the
``config`` block; trajectories are only comparable within one
(machine, backend, jax) triple.
"""
from __future__ import annotations

import json
import os
import platform as platform_mod
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import numpy as np

from repro.configs.base import AmmConfig
from repro.kernels import flash_attention, flash_attention_amm, on_tpu
from repro.models.attention import chunked_attention, flash_amm_chunked_equiv
from repro.models.common import AmmRuntime

# wl=16 operating point of the paper's Type-0 multiplier; d=64 head dim
POINT = ("bbm0", 16, 13)
CONTEXTS = [1024, 4096, 16384]
SMOKE_CONTEXTS = [256]
HEADS, HEAD_DIM = 1, 64


def _time_many(fns, repeats: int = 3) -> list[float]:
    """Median wall times, measured round-robin (see filterbank.py)."""
    for fn in fns:
        fn()                               # warm-up / compile
    ts = [[] for _ in fns]
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            ts[i].append(time.perf_counter() - t0)
    return [float(np.median(t)) for t in ts]


def _qkv(s, seed=0):
    rng = np.random.default_rng(seed)
    shape = (1, HEADS, s, HEAD_DIM)
    q = jax.numpy.asarray(rng.standard_normal(shape), jax.numpy.float32)
    k = jax.numpy.asarray(rng.standard_normal(shape), jax.numpy.float32)
    v = jax.numpy.asarray(rng.standard_normal(shape), jax.numpy.float32)
    return q, k, v


def attention_sweep(smoke: bool = False, out: str | None = None):
    mul, wl, vbl = POINT
    rt = AmmRuntime.build(AmmConfig(mode="bitexact", mul=mul, wl=wl,
                                    param=vbl, apply_to="all"))
    wl_, vbl_, kind = rt.attn_lowering
    contexts = SMOKE_CONTEXTS if smoke else CONTEXTS
    rows = []
    speedup_at_max = 0.0
    for s in contexts:
        q, k, v = _qkv(s)
        # (B, S, H, D) layout for the chunked schedule
        qs, ks, vs = (t.transpose(0, 2, 1, 3) for t in (q, k, v))

        def run_flash_amm():
            return jax.block_until_ready(flash_attention_amm(
                q, k, v, wl=wl_, vbl=vbl_, kind=kind, causal=True))

        def run_chunked_amm():
            return jax.block_until_ready(chunked_attention(
                qs, ks, vs, causal=True, amm=rt))

        def run_exact_flash():
            return jax.block_until_ready(flash_attention(
                q, k, v, causal=True, interpret=not on_tpu()))

        repeats = 2 if (not smoke and s >= CONTEXTS[-1]) else 3
        t_flash, t_chunked, t_exact = _time_many(
            [run_flash_amm, run_chunked_amm, run_exact_flash],
            repeats=repeats)
        for cell, t in (("flash_amm", t_flash), ("chunked_amm", t_chunked),
                        ("exact_flash", t_exact)):
            rows.append({"cell": cell, "context": s, "heads": HEADS,
                         "head_dim": HEAD_DIM, "mul": mul, "wl": wl,
                         "vbl": vbl, "us_per_call": t * 1e6,
                         "tokens_per_s": s / t})
        speedup_at_max = t_chunked / t_flash

    # bit-exactness checkpoint at the smallest context: flash-amm vs the
    # chunked schedule at the flash tiles (matched per-block scales)
    s = contexts[0]
    q, k, v = _qkv(s, seed=1)
    got = np.asarray(flash_attention_amm(q, k, v, wl=wl_, vbl=vbl_,
                                         kind=kind, causal=True))
    ref = np.asarray(flash_amm_chunked_equiv(q, k, v, rt, causal=True))
    bitexact = bool(np.array_equal(got, ref))

    derived = {
        "flash_amm_bitexact": int(bitexact),
        "flash_amm_speedup": speedup_at_max,
        "speedup_context": contexts[-1],
        "cells": len(rows),
    }
    if out:
        config = {
            "smoke": smoke, "on_tpu": on_tpu(),
            "point": {"mul": mul, "wl": wl, "vbl": vbl},
            "exact_flash_interpreted": not on_tpu(),
            "flash_amm_lowering": "pallas" if on_tpu() else "xla",
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "numpy_version": np.__version__,
            "python_version": platform_mod.python_version(),
            "platform": platform_mod.platform(),
            "machine": platform_mod.machine(),
            "cpu_count": os.cpu_count(),
        }
        with open(out, "w") as f:
            json.dump({"config": config, "derived": derived, "rows": rows},
                      f, indent=1)
    return rows, derived


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="reduced configuration for CI")
    p.add_argument("--out", default="BENCH_attention.json",
                   help="results file")
    args = p.parse_args(argv)
    _, derived = attention_sweep(smoke=args.smoke, out=args.out)
    print(json.dumps(derived, indent=1, sort_keys=True))
    # CI gate: the flash schedule must reproduce the chunked datapath bit
    # for bit; throughput is reported, not gated (runner-dependent)
    return 0 if derived["flash_amm_bitexact"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
