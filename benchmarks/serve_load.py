"""Serving-load benchmark: continuous batching + int-code KV cache.

Three questions, one artifact (``BENCH_serve.json``):

  * **Scheduling** — what does continuous batching buy under Poisson
    arrivals?  The same arrival stream drives the legacy flush-wave
    discipline (a wave is admitted only when every slot is idle, the
    whole wave decodes in lockstep) and the continuous scheduler
    (per-step admission, per-request eviction).  Reported per mode:
    p50/p95/p99 request latency in scheduler steps, wall time, and
    tokens/s per user (each request's generated tokens over its own
    residency).
  * **Memory** — the int-code cache's byte accounting vs the bf16 float
    cache it replaces (``serve.kv_cache.memory_report``): at wl=8 the
    code planes are exactly half the bf16 bytes, and the per-block f32
    scale planes are reported separately.
  * **CI gates** (``--smoke``) — the conformance contracts this PR
    claims: every request's token stream under continuous batching with
    the int-code cache is *bitwise* its solo-run stream (attention-side
    amm routing; tests/test_serve_continuous.py sweeps interleavings),
    and the headline code-vs-bf16 byte ratio is >= 2x.

Latency percentiles are measured in scheduler steps (deterministic);
wall-clock numbers ride along for context and are host-dependent.
"""
from __future__ import annotations

import json
import os
import platform as platform_mod
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import dataclasses

import jax
import numpy as np

from repro.configs import get_arch, reduced
from repro.configs.base import AmmConfig
from repro.models import ModelRuntime, lm_init
from repro.serve.engine import Request, Scheduler
from repro.serve.kv_cache import memory_report

WL, VBL = 8, 5


def build_lm():
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode="bitexact", mul="bbm0", wl=WL, param=VBL,
                           apply_to="attn"))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    return cfg, rt, params


def poisson_workload(rng, vocab, *, n_requests, rate):
    """[(arrival_step, prompt, max_new)] with exponential inter-arrivals."""
    arrivals, t = [], 0.0
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        plen = int(rng.integers(2, 9))
        arrivals.append((int(t), rng.integers(1, vocab, plen).tolist(),
                         int(rng.integers(2, 6))))
    return arrivals


def _percentiles(lat):
    return {f"p{p}": float(np.percentile(lat, p)) for p in (50, 95, 99)}


def run_continuous(lm, arrivals, *, slots, max_len, kv_codes):
    """Continuous scheduler under the arrival stream; per-request stats."""
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, slots, max_len, continuous=True,
                      kv_codes=kv_codes)
    reqs, born = [], {}
    t, idx = 0, 0
    t0 = time.perf_counter()
    while True:
        while idx < len(arrivals) and arrivals[idx][0] <= t:
            _, prompt, max_new = arrivals[idx]
            r = Request(rid=idx, prompt=prompt, max_new=max_new)
            born[idx] = t
            reqs.append(r)
            sched.submit(r)
            idx += 1
        live = sched.step()
        t += 1
        for r in reqs:
            if r.done and not hasattr(r, "_lat"):
                r._lat = t - born[r.rid]
        if live == 0 and idx >= len(arrivals) and not sched.queue:
            break
    wall = time.perf_counter() - t0
    return _collect(reqs, t, wall)


def run_flush_waves(lm, arrivals, *, slots, max_len):
    """Legacy discipline: a wave admits only once every slot is idle."""
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, slots, max_len)
    reqs, born, pend = [], {}, []
    t, idx = 0, 0
    t0 = time.perf_counter()
    while True:
        while idx < len(arrivals) and arrivals[idx][0] <= t:
            _, prompt, max_new = arrivals[idx]
            r = Request(rid=idx, prompt=prompt, max_new=max_new)
            born[idx] = t
            reqs.append(r)
            pend.append(r)
            idx += 1
        if all(s is None for s in sched.slots) and not sched.queue:
            for r in pend[:slots]:
                sched.submit(r)
            pend = pend[slots:]
        live = sched.step()
        t += 1
        for r in reqs:
            if r.done and not hasattr(r, "_lat"):
                r._lat = t - born[r.rid]
        if live == 0 and idx >= len(arrivals) and not pend \
                and not sched.queue:
            break
    wall = time.perf_counter() - t0
    return _collect(reqs, t, wall)


def _collect(reqs, steps, wall):
    lat = [r._lat for r in reqs]
    toks = sum(len(r.out) for r in reqs)
    per_user = [len(r.out) / (r._lat * wall / max(steps, 1))
                for r in reqs if r._lat > 0]
    return {"requests": len(reqs), "steps": steps, "wall_s": wall,
            "total_tokens": toks,
            "tokens_per_s": toks / wall,
            "tokens_per_s_per_user": float(np.mean(per_user)),
            "latency_steps": _percentiles(lat),
            "streams": {r.rid: list(r.out) for r in reqs},
            "all_ok": all(r.done and r.error is None for r in reqs)}


# ------------------------------------------------------------ smoke gates
def gate_solo_bitwise(lm, arrivals, *, slots, max_len) -> int:
    """Every continuous+kv_codes stream == its solo-run stream, bitwise."""
    batched = run_continuous(lm, arrivals, slots=slots, max_len=max_len,
                             kv_codes=True)
    if not batched["all_ok"]:
        return 0
    cfg, rt, params = lm
    for rid, (_, prompt, max_new) in enumerate(arrivals):
        sched = Scheduler(cfg, rt, params, slots, max_len, continuous=True,
                          kv_codes=True)
        solo = Request(rid=0, prompt=list(prompt), max_new=max_new)
        sched.submit(solo)
        while sched.step():
            pass
        if solo.out != batched["streams"][rid]:
            return 0
    return 1


def gate_memory_ratio(rep) -> int:
    return int(rep["ratio_codes"] >= 2.0)


def serve_load(smoke: bool = False, out: str | None = None):
    rows: list = []
    slots = 2 if smoke else 4
    max_len = 32 if smoke else 64
    n_req = 6 if smoke else 16
    lm = build_lm()
    cfg = lm[0]
    rng = np.random.default_rng(5)
    arrivals = poisson_workload(rng, cfg.vocab, n_requests=n_req, rate=0.7)

    modes = {
        "flush_waves_float": run_flush_waves(lm, arrivals, slots=slots,
                                             max_len=max_len),
        "continuous_float": run_continuous(lm, arrivals, slots=slots,
                                           max_len=max_len, kv_codes=False),
        "continuous_codes": run_continuous(lm, arrivals, slots=slots,
                                           max_len=max_len, kv_codes=True),
    }
    for name, m in modes.items():
        rows.append({"bench": "serve_load", "mode": name,
                     **{k: v for k, v in m.items() if k != "streams"}})

    rep = memory_report(cfg, slots, max_len, wl=WL)
    rows.append({"bench": "kv_cache_bytes", "wl": WL, **rep})

    gates = {"solo_vs_batched_bitwise":
             gate_solo_bitwise(lm, arrivals[:4], slots=slots,
                               max_len=max_len),
             "code_cache_memory_2x": gate_memory_ratio(rep)}

    derived = dict(gates)
    derived.update({
        "all_requests_served": int(all(m["all_ok"] for m in modes.values())),
        "latency_p50_flush": modes["flush_waves_float"]["latency_steps"]["p50"],
        "latency_p50_continuous": modes["continuous_codes"]["latency_steps"]["p50"],
        "latency_p95_flush": modes["flush_waves_float"]["latency_steps"]["p95"],
        "latency_p95_continuous": modes["continuous_codes"]["latency_steps"]["p95"],
        "latency_p99_flush": modes["flush_waves_float"]["latency_steps"]["p99"],
        "latency_p99_continuous": modes["continuous_codes"]["latency_steps"]["p99"],
        "tokens_per_s_per_user_continuous":
            modes["continuous_codes"]["tokens_per_s_per_user"],
        "cache_bytes_codes": rep["code_bytes"],
        "cache_bytes_scales": rep["scale_bytes"],
        "cache_bytes_bf16": rep["bf16_bytes"],
        "cache_ratio_codes": rep["ratio_codes"],
        "cache_ratio_total": rep["ratio_total"],
        "cells": len(rows),
    })
    if out:
        config = {
            "smoke": smoke, "slots": slots, "max_len": max_len,
            "n_requests": n_req, "wl": WL, "vbl": VBL,
            "arch": "qwen2-0.5b (reduced)", "apply_to": "attn",
            "jax_backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "numpy_version": np.__version__,
            "python_version": platform_mod.python_version(),
            "platform": platform_mod.platform(),
            "machine": platform_mod.machine(),
            "cpu_count": os.cpu_count(),
        }
        with open(out, "w") as f:
            json.dump({"config": config, "derived": derived, "rows": rows},
                      f, indent=1)
    return rows, derived


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="reduced configuration for CI")
    p.add_argument("--out", default="BENCH_serve.json", help="results file")
    args = p.parse_args(argv)
    _, derived = serve_load(smoke=args.smoke, out=args.out)
    print(json.dumps(derived, indent=1, sort_keys=True))
    # CI gate: the solo-vs-batched bitwise conformance contract and the
    # code-cache memory claim must both hold
    return 0 if derived["solo_vs_batched_bitwise"] \
        and derived["code_cache_memory_2x"] \
        and derived["all_requests_served"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
