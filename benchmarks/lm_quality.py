"""Beyond-paper benchmark: the paper's SNR-vs-power tradeoff at LM scale.

Trains a reduced qwen2 under exact vs approximate multipliers and reports
the loss penalty next to the modeled multiplier power saving — the LM
analogue of Table IV.  Each approximate cell now carries *two* loss
columns:

  loss_noise     — §II.B white-noise proxy (quantize -> exact matmul ->
                   calibrated noise), the scalable path;
  loss_bitexact  — the true Broken-Booth datapath, lowered to dense
                   contractions (``amm_dense`` mode="bitexact" on
                   ``kernels.bbm_matmul_scaled``), affordable at model
                   scale since the exact-dot + low-bit-correction rewrite.

so the noise model is validated (or falsified) against the silicon it
models, at the workload the repo actually cares about.

Attention-routing cells: since attention's QK^T/PV products run on the
same datapath (``models.common.amm_dot``; docs/attention.md), the sweep
also reports ``loss_bitexact`` for ``apply_to`` in {mlp, attn, all} at
the paper's bbm0/13 operating point — isolating the attention
contribution to the quality cost from the MLP contribution.

Derived metrics:

  lm_bitexact_matches_oracle — 1 iff the dot-form datapath is bitwise
      equal to the retained scalar oracle (``kernels.ref.amm_dense_ref``)
      on this model's own MLP weights; CI gates on it.
  attn_bitexact_matches_oracle — 1 iff the attention datapath is bitwise
      equal to the scalar attention oracle
      (``kernels.ref.amm_attention_ref`` / ``amm_decode_attention_ref``)
      at this model's own head shapes; CI gates on it too.
  worst_noise_model_gap — max |loss_bitexact - loss_noise| across cells.
  worst_attn_loss_penalty — max loss penalty across the routing cells.

Used by `benchmarks.run` when --full is set (it costs a few minutes);
``python benchmarks/lm_quality.py --smoke`` is the CI gate (short runs,
nonzero exit on oracle mismatch), `examples/dse_sweep.py` the interactive
version.
"""
from __future__ import annotations

import dataclasses
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import AmmConfig, get_arch, reduced
from repro.core.hwmodel import power
from repro.core.multipliers import MulSpec
from repro.data.pipeline import DataConfig, global_batch
from repro.kernels.ref import amm_dense_ref
from repro.launch.mesh import make_host_mesh
from repro.models import ModelRuntime, lm_init
from repro.models.common import AmmRuntime, amm_dense
from repro.train.optimizer import OptConfig
from repro.train.trainstep import TrainConfig, init_train_state, \
    make_train_step

STEPS = 10
CELLS = (("bbm0", 13), ("bbm0", 15), ("bbm1", 13))
# attention-routing cells, all at the paper's bbm0/13 operating point
ATTN_CELLS = ("mlp", "attn", "all")


def _cfg(mode: str, mul: str, vbl: int, apply_to: str = "mlp"):
    cfg = reduced(get_arch("qwen2-0.5b"))
    return dataclasses.replace(
        cfg, amm=AmmConfig(mode=mode, mul=mul, wl=16, param=vbl,
                           apply_to=apply_to))


def _run(mode: str, mul: str, vbl: int, steps: int = STEPS,
         apply_to: str = "mlp") -> float:
    cfg = _cfg(mode, mul, vbl, apply_to)
    rt = ModelRuntime.build(cfg)
    mesh = make_host_mesh(1, 1)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=steps))
    step = make_train_step(cfg, rt, tc, mesh, global_batch=4)
    params, opt = init_train_state(cfg, tc, mesh, jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    loss = 0.0
    for i in range(steps):
        t, l = global_batch(dc, i)
        params, opt, m = step(params, opt, jnp.asarray(t), jnp.asarray(l),
                              jax.random.fold_in(jax.random.key(1), i))
        loss = float(m["loss"])
    return loss


def _cell_ok(x, w, rt, spec) -> bool:
    """Bitwise oracle equality + an oracle-independent sanity bound.

    The equality alone cannot catch a defect *shared* with the oracle
    (both sit on ``kernels.ref.amm_quantize``), so the approximate output
    is also held to the analytic error budget against the true float
    matmul: per product, truncation removes at most ``R * 2^vbl`` in the
    integer domain and quantization at most half a code step per operand.
    A quantizer regression (e.g. the bf16 wraparound that flips the sign
    of full-scale activations) blows this budget by orders of magnitude.
    """
    got = np.asarray(amm_dense(x, w, rt), np.float64)
    ref = np.asarray(amm_dense_ref(x, w, spec), np.float64)
    if not np.array_equal(got, ref):
        return False
    exact = np.asarray(jnp.asarray(x, jnp.float32) @ w, np.float64)
    k = x.shape[-1]
    lim = 2 ** (spec.wl - 1) - 1
    s_x = max(float(np.max(np.abs(np.asarray(x, np.float64)))) / lim, 1e-12)
    s_w = max(float(np.max(np.abs(np.asarray(w, np.float64)))) / lim, 1e-12)
    r_rows = (spec.param + 1) // 2
    budget = k * (r_rows * 2.0 ** spec.param * s_x * s_w          # truncation
                  + 0.5 * s_x * np.max(np.abs(np.asarray(w)))     # quant x
                  + 0.5 * s_w * np.max(np.abs(np.asarray(x, np.float64)))
                  + 0.5 * s_x * s_w)                              # cross term
    # 2x headroom: the per-term bounds interact (Type1's +S dots, f32
    # combine rounding) and sit within a few percent of the sum above;
    # the defect class this guards against — e.g. a wrapped full-scale
    # code — overshoots the budget by ~1000x, so the slack costs nothing
    return bool(np.max(np.abs(got - exact)) <= 2 * budget)


def bitexact_matches_oracle() -> bool:
    """Dot-form ``amm_dense`` == scalar oracle on this model's weights.

    Uses the reduced qwen2 config's own initialized MLP parameters (the
    exact tensors a bitexact serve run contracts against) and activations
    in **bfloat16** — the dtype ``lm_apply`` actually feeds the MLPs — at
    the model's hidden width: the workload-shaped instance of the
    equality the unit sweep (tests/test_amm_bitexact.py) proves on grids.
    Every distinct sweep cell is checked, so both truncation kinds (bbm0
    and bbm1) gate CI, not just the default.
    """
    params = None
    rng = np.random.default_rng(7)
    ok = True
    for mul, vbl in CELLS:
        cfg = _cfg("bitexact", mul, vbl)
        rt = AmmRuntime.build(cfg.amm)
        spec = MulSpec(cfg.amm.mul, cfg.amm.wl, cfg.amm.param)
        if params is None:
            params = lm_init(cfg, jax.random.key(0))
        mlp = jax.tree.map(lambda p: p[0], params["layers"]["mlp"])
        x = jnp.asarray(rng.standard_normal((8, cfg.d_model)), jnp.bfloat16)
        for name in ("w_gate", "w_up"):
            ok = ok and _cell_ok(x, mlp[name], rt, spec)
        h = jnp.asarray(rng.standard_normal((8, cfg.d_ff)), jnp.bfloat16)
        ok = ok and _cell_ok(h, mlp["w_down"], rt, spec)
    return bool(ok)


def attn_bitexact_matches_oracle() -> bool:
    """Attention datapath == scalar attention oracle at the LM's shapes.

    Drives ``chunked_attention`` (prefill schedule) and
    ``decode_attention`` (cache schedule, dead zero tail) at the reduced
    qwen2's own head geometry — n_heads, n_kv_heads, head_dim — across
    every sweep cell, so both truncation kinds gate CI.  Equality is
    bitwise (``kernels.ref`` shares the schedule, oracles the products;
    docs/attention.md).
    """
    from repro.models.attention import chunked_attention, decode_attention
    from repro.kernels.ref import (amm_attention_ref,
                                   amm_decode_attention_ref)
    cfg = reduced(get_arch("qwen2-0.5b"))
    h, kv, d = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(19)
    q = jnp.asarray(rng.standard_normal((2, 16, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 16, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 16, kv, d)), jnp.float32)
    qd = jnp.asarray(rng.standard_normal((2, 1, h, d)), jnp.float32)
    kc = np.zeros((2, 16, kv, d), np.float32)
    vc = np.zeros((2, 16, kv, d), np.float32)
    kc[:, :11] = rng.standard_normal((2, 11, kv, d))
    vc[:, :11] = rng.standard_normal((2, 11, kv, d))
    kc, vc = jnp.asarray(kc), jnp.asarray(vc)
    ok = True
    for mul, vbl in CELLS:
        rt = AmmRuntime.build(AmmConfig(mode="bitexact", mul=mul, wl=16,
                                        param=vbl, apply_to="all"))
        spec = MulSpec(mul, 16, vbl)
        got = np.asarray(chunked_attention(q, k, v, causal=True, bq=8,
                                           bk=8, amm=rt))
        ref = np.asarray(amm_attention_ref(q, k, v, spec, causal=True,
                                           bq=8, bk=8))
        ok = ok and np.array_equal(got, ref)
        got_d = np.asarray(decode_attention(qd, kc, vc, 11, amm=rt))
        ref_d = np.asarray(amm_decode_attention_ref(qd, kc, vc, 11, spec))
        ok = ok and np.array_equal(got_d, ref_d)
    return bool(ok)


def lm_quality(steps: int = STEPS):
    base = _run("off", "bbm0", 0, steps)
    rows = [{"mul": "exact", "vbl": 0, "loss_noise": base,
             "loss_bitexact": base, "power_saving_pct": 0.0}]
    p0 = power(MulSpec("bbm0", 16, 0))
    for mul, vbl in CELLS:
        rows.append({
            "mul": mul, "vbl": vbl,
            "loss_noise": _run("noise", mul, vbl, steps),
            "loss_bitexact": _run("bitexact", mul, vbl, steps),
            "power_saving_pct":
                100 * (1 - power(MulSpec(mul, 16, vbl)) / p0)})
    # attention-routing cells: the whole-forward trajectory at bbm0/13.
    # The "mlp" cell IS the bbm0/13 sweep cell just trained (apply_to
    # defaults to "mlp" in _run) — reuse its loss instead of re-training.
    mlp_13 = next(r["loss_bitexact"] for r in rows
                  if r["mul"] == "bbm0" and r["vbl"] == 13)
    attn_rows = [{"mul": "bbm0", "vbl": 13, "apply_to": ap,
                  "loss_bitexact": (mlp_13 if ap == "mlp"
                                    else _run("bitexact", "bbm0", 13, steps,
                                              apply_to=ap))}
                 for ap in ATTN_CELLS]
    worst = max(r["loss_bitexact"] - base for r in rows[1:])
    gap = max(abs(r["loss_bitexact"] - r["loss_noise"]) for r in rows[1:])
    return rows + attn_rows, {
        "base_loss": base, "worst_loss_penalty": worst,
        "worst_noise_model_gap": gap,
        "worst_attn_loss_penalty": max(r["loss_bitexact"] - base
                                       for r in attn_rows),
        "lm_bitexact_matches_oracle": int(bitexact_matches_oracle()),
        "attn_bitexact_matches_oracle": int(attn_bitexact_matches_oracle()),
        "max_power_saving_pct": max(r["power_saving_pct"] for r in rows)}


def smoke() -> int:
    """CI gate: short bit-exact cells + oracle equality at the LM config.

    Exit 1 when the dot-form datapath diverges from the scalar oracle
    (MLP *or* attention side), or any loss — including the attention
    routing cells apply_to in {attn, all} — goes non-finite: the
    model-scale analogue of the filterbank smoke's kernel_bitexact /
    dotform_bitexact gates.
    """
    match = bitexact_matches_oracle()
    attn_match = attn_bitexact_matches_oracle()
    base = _run("off", "bbm0", 0, steps=2)
    bit = _run("bitexact", "bbm0", 13, steps=2)
    noise = _run("noise", "bbm0", 13, steps=2)
    bit_attn = _run("bitexact", "bbm0", 13, steps=2, apply_to="attn")
    bit_all = _run("bitexact", "bbm0", 13, steps=2, apply_to="all")
    out = {"lm_bitexact_matches_oracle": int(match),
           "attn_bitexact_matches_oracle": int(attn_match),
           "base_loss": base, "loss_bitexact": bit, "loss_noise": noise,
           "loss_bitexact_attn": bit_attn, "loss_bitexact_all": bit_all}
    print(json.dumps(out, sort_keys=True))
    finite = all(np.isfinite(v)
                 for v in (base, bit, noise, bit_attn, bit_all))
    if not match:
        print("FAIL: dot-form amm_dense != scalar oracle", file=sys.stderr)
    if not attn_match:
        print("FAIL: amm attention != scalar attention oracle",
              file=sys.stderr)
    if not finite:
        print("FAIL: non-finite loss", file=sys.stderr)
    return 0 if (match and attn_match and finite) else 1


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        raise SystemExit(smoke())
    rows, derived = lm_quality()
    for r in rows:
        print(r)
    print(json.dumps(derived, sort_keys=True))
