"""Beyond-paper benchmark: the paper's SNR-vs-power tradeoff at LM scale.

Trains a reduced qwen2 under exact vs approximate (noise-model) multipliers
and reports the loss penalty next to the modeled multiplier power saving —
the LM analogue of Table IV.  Used by `benchmarks.run` when --full is set
(it costs ~1 min); `examples/dse_sweep.py` is the interactive version.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import AmmConfig, get_arch, reduced
from repro.core.hwmodel import power
from repro.core.multipliers import MulSpec
from repro.data.pipeline import DataConfig, global_batch
from repro.launch.mesh import make_host_mesh
from repro.models import ModelRuntime
from repro.train.optimizer import OptConfig
from repro.train.trainstep import TrainConfig, init_train_state, \
    make_train_step

STEPS = 10


def _run(mode: str, mul: str, vbl: int) -> float:
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode=mode, mul=mul, wl=16, param=vbl))
    rt = ModelRuntime.build(cfg)
    mesh = make_host_mesh(1, 1)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=STEPS))
    step = make_train_step(cfg, rt, tc, mesh, global_batch=4)
    params, opt = init_train_state(cfg, tc, mesh, jax.random.key(0))
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=4)
    loss = 0.0
    for i in range(STEPS):
        t, l = global_batch(dc, i)
        params, opt, m = step(params, opt, jnp.asarray(t), jnp.asarray(l),
                              jax.random.fold_in(jax.random.key(1), i))
        loss = float(m["loss"])
    return loss


def lm_quality():
    base = _run("off", "bbm0", 0)
    rows = [{"mul": "exact", "vbl": 0, "loss": base, "power_saving_pct": 0.0}]
    p0 = power(MulSpec("bbm0", 16, 0))
    for mul, vbl in (("bbm0", 13), ("bbm0", 15), ("bbm1", 13)):
        loss = _run("noise", mul, vbl)
        rows.append({"mul": mul, "vbl": vbl, "loss": loss,
                     "power_saving_pct":
                         100 * (1 - power(MulSpec(mul, 16, vbl)) / p0)})
    worst = max(r["loss"] - base for r in rows[1:])
    return rows, {"base_loss": base, "worst_loss_penalty": worst,
                  "max_power_saving_pct": max(r["power_saving_pct"]
                                              for r in rows)}
