"""FIR filter + SNR testbed tests (paper §III.C reproduction)."""
import numpy as np
import pytest

from repro.core.multipliers import MulSpec
from repro.dsp import (FIR_DELAY, design_lowpass, fir_apply_fixed,
                       fir_apply_real, make_signals, quantize, dequantize,
                       run_filter_case, snr_db)


@pytest.fixture(scope="module")
def sig():
    return make_signals(n=1 << 13, seed=0)


def test_quantize_roundtrip():
    x = np.linspace(-0.999, 0.999, 1001)
    import jax.numpy as jnp
    from repro.core.booth import to_signed
    q = quantize(jnp.asarray(x), 12)
    back = np.asarray(dequantize(to_signed(q, 12), 12))
    assert np.abs(back - x).max() <= 2.0 ** -12 + 1e-9


def test_filter_design_is_lowpass():
    h = design_lowpass()
    w = np.linspace(0, np.pi, 512)
    H = np.abs(np.exp(-1j * np.outer(w, np.arange(len(h)))) @ h)
    passband = H[w <= 0.25 * np.pi]
    stopband = H[w >= 0.35 * np.pi]
    assert passband.min() > 0.9
    assert stopband.max() < 0.25


def test_double_precision_snr_matches_paper(sig):
    out = run_filter_case(None, sig)
    assert out == pytest.approx(25.7, abs=0.6)          # paper: 25.7 dB
    snr_in = snr_db(sig.d1, sig.x, 0)
    assert snr_in == pytest.approx(-3.2, abs=0.6)       # paper: -3.47 dB


def test_fixed_point_wl16_close_to_double(sig):
    out = run_filter_case(MulSpec("booth", 16, 0), sig)
    ref = run_filter_case(None, sig)
    assert abs(out - ref) < 0.1                          # paper: 25.4 vs 25.7


def test_paper_snr_penalty_golden(sig):
    """Golden regression for the paper's headline number (§III.C).

    The proposed Broken-Booth multiplier at its operating point costs
    ~0.4 dB of 30-tap-FIR SNR against the exact Booth datapath (paper:
    25.4 dB vs 25.7 dB at WL=16).  Pinned tight so a datapath refactor
    cannot silently drift the claim: measured 0.373 dB on the seed
    signals (n = 2^13, seed 0).
    """
    base = run_filter_case(MulSpec("booth", 16, 0), sig)
    prop = run_filter_case(MulSpec("bbm0", 16, 15), sig)
    assert base - prop == pytest.approx(0.4, abs=0.15)


def test_vbl_degrades_gracefully(sig):
    """Paper Fig 8(b): steady SNR reduction as VBL grows."""
    h = design_lowpass()
    snrs = []
    for vbl in (0, 13, 15, 17, 19):
        y = fir_apply_fixed(sig.x, h, MulSpec("bbm0", 16, vbl))
        snrs.append(snr_db(sig.d1, y, FIR_DELAY))
    assert all(a >= b - 0.05 for a, b in zip(snrs, snrs[1:]))
    # paper's operating criterion: a VBL with ~0.4 dB loss exists
    assert snrs[0] - snrs[2] < 1.0                       # VBL=15 mild
    assert snrs[0] - snrs[4] > 2.0                       # VBL=19 significant


def test_wlbit_datapath_cliff(sig):
    """Paper Fig 8(a): small WL collapses SNR on the wl-bit datapath."""
    h = design_lowpass()
    y8 = fir_apply_fixed(sig.x, h, MulSpec("booth", 8, 0), datapath="wlbit")
    y16 = fir_apply_fixed(sig.x, h, MulSpec("booth", 16, 0), datapath="wlbit")
    s8, s16 = snr_db(sig.d1, y8, FIR_DELAY), snr_db(sig.d1, y16, FIR_DELAY)
    assert s16 - s8 > 3.0


def test_exact_path_matches_jax_path(sig):
    """int64 numpy exact path == jax booth path at wl=16."""
    h = design_lowpass()
    a = fir_apply_fixed(sig.x[:512], h, MulSpec("booth", 16, 0))
    b = fir_apply_fixed(sig.x[:512], h, MulSpec("bbm0", 16, 0))
    np.testing.assert_allclose(a, b, atol=1e-12)


def test_approx_filter_output_bounded(sig):
    h = design_lowpass()
    y = fir_apply_fixed(sig.x[:2048], h, MulSpec("bbm0", 16, 13))
    yr = fir_apply_real(sig.x[:2048], h)
    # approximate output stays close to the reference in absolute terms
    assert np.mean((y - yr) ** 2) < 1e-3 * np.var(yr) + 1e-6
