"""Precoded Booth-digit datapath tests.

The decode/accumulate split promises: ``booth_precode`` + the multiply-free
``bbm_rows_product_precoded`` are bit-for-bit equal to the closed forms in
``core.bbm`` and to the raw-code row loop; the precoded FIR and matmul
kernels equal their raw-code wrappers across wl x vbl x kind; a
``PrecodedBank`` behaves exactly like raw taps through ``fir_apply``; and
``FilterbankEngine`` decodes its banks exactly once, at construction,
reusing the planes across flush rounds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bbm import bbm_mul
from repro.core.booth import booth_digits
from repro.core.multipliers import MulSpec
from repro.dsp import PrecodedBank, design_lowpass, fir_apply
from repro.kernels import (bbm_matmul, bbm_matmul_precoded, booth_precode,
                           fir_bbm_bank, fir_bbm_bank_precoded,
                           min_safe_shift)
from repro.kernels.booth_rows import (bbm_rows_product,
                                      bbm_rows_product_precoded,
                                      split_signed)

RNG = np.random.default_rng(11)

# (wl, vbl) sweep points; kind 0/1 covers bbm0/bbm1
SWEEP = [(8, 0), (8, 5), (12, 7), (12, 11), (16, 13), (16, 15)]


def test_precode_planes_match_booth_digits():
    """Exhaustive wl=8: (mag, neg) planes == |d|, neg of ``booth_digits``."""
    wl = 8
    b = jnp.arange(1 << wl, dtype=jnp.int32)
    mag, neg = booth_precode(b, wl)
    assert mag.shape == (wl // 2, 1 << wl)
    d, hw_neg = booth_digits(b, wl)          # row axis last
    np.testing.assert_array_equal(np.asarray(mag), np.abs(np.asarray(d)).T)
    np.testing.assert_array_equal(np.asarray(neg), np.asarray(hw_neg).T)


# ------------------------------------------------------------ row-loop level
@pytest.mark.parametrize("wl,vbl", SWEEP)
@pytest.mark.parametrize("kind", [0, 1])
def test_precoded_rows_match_bbm_mul(wl, vbl, kind):
    """Both accumulate forms == closed-form bbm_mul, bit for bit.

    ``multiply_free=True`` is the silicon/TPU select form, ``False`` the
    one-multiply-per-row form XLA prefers on CPU — same planes, same bits.
    """
    a = jnp.asarray(RNG.integers(0, 1 << wl, 4096), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 1 << wl, 4096), jnp.int32)
    _, a_s = split_signed(a, wl)
    mag, neg = booth_precode(b, wl)
    ref = bbm_mul(a, b, wl, vbl, kind=kind)
    for multiply_free in (True, False):
        got = bbm_rows_product_precoded(a_s, mag, neg, wl=wl, vbl=vbl,
                                        kind=kind,
                                        multiply_free=multiply_free)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                      err_msg=f"multiply_free={multiply_free}")
    # the raw-code wrapper is decode + accumulate and must agree too
    raw = bbm_rows_product(a_s, b & ((1 << wl) - 1), wl=wl, vbl=vbl,
                           kind=kind)
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(ref))


# --------------------------------------------------------------- kernel level
@pytest.mark.parametrize("wl,vbl", SWEEP)
@pytest.mark.parametrize("kind", [0, 1])
def test_fir_kernel_raw_vs_precoded(wl, vbl, kind):
    """Raw-code and precoded-planes kernel entry points are bit-identical."""
    channels, n, taps = 4, 512, 31
    shift = min_safe_shift(taps, wl)
    x = jnp.asarray(RNG.integers(0, 1 << wl, (channels, n)), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, (channels, taps)), jnp.int32)
    raw = fir_bbm_bank(x, h, wl=wl, vbl=vbl, kind=kind, shift=shift,
                       bc=2, bt=128, interpret=True, form="rows")
    hmag, hneg = booth_precode(h, wl)
    pre = fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                shift=shift, bc=2, bt=128, interpret=True,
                                form="rows")
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(pre))


@pytest.mark.parametrize("wl,vbl", [(8, 5), (12, 7), (16, 13)])
@pytest.mark.parametrize("kind", [0, 1])
def test_bbm_matmul_raw_vs_precoded(wl, vbl, kind):
    """Precoded matmul == raw wrapper == closed-form accumulation."""
    m, k, n = 8, 32, 8
    shift = min_safe_shift(k, wl)
    x = jnp.asarray(RNG.integers(0, 1 << wl, (m, k)), jnp.int32)
    w = jnp.asarray(RNG.integers(0, 1 << wl, (k, n)), jnp.int32)
    raw = bbm_matmul(x, w, wl=wl, vbl=vbl, kind=kind, shift=shift,
                     bm=8, bk=16, bn=8, interpret=True, form="rows")
    wmag, wneg = booth_precode(w, wl)
    pre = bbm_matmul_precoded(x, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                              shift=shift, bm=8, bk=16, bn=8, interpret=True,
                              form="rows")
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(pre))
    prod = np.asarray(bbm_mul(x[:, :, None], w[None, :, :], wl, vbl,
                              kind=kind), np.int64)
    ref = np.sum(prod >> shift, axis=1)
    np.testing.assert_array_equal(np.asarray(pre, np.int64), ref)


def test_precoded_kernel_rejects_mismatched_planes():
    x = jnp.zeros((2, 64), jnp.int32)
    hmag, hneg = booth_precode(jnp.zeros((2, 5), jnp.int32), 12)
    with pytest.raises(ValueError, match="plane shapes differ"):
        fir_bbm_bank_precoded(x, hmag, hneg[:1], wl=12, vbl=0,
                              interpret=True)
    with pytest.raises(ValueError, match="wl"):
        fir_bbm_bank_precoded(x, hmag, hneg, wl=8, vbl=0, interpret=True)


# ------------------------------------------------------------------ dsp level
@pytest.mark.parametrize("backend", ["host", "pallas-interpret"])
def test_fir_apply_precoded_bank_matches_raw_taps(backend):
    """fir_apply(x, PrecodedBank) == fir_apply(x, raw taps), both backends."""
    spec = MulSpec("bbm0", 16, 13)
    x = RNG.standard_normal((4, 500))
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    idx = [0, 1, 1, 0]
    raw = fir_apply(x, banks[idx], spec, backend=backend, block=128, bc=2)
    bank = PrecodedBank(banks, spec).take(idx)
    pre = fir_apply(x, bank, backend=backend, block=128, bc=2)
    np.testing.assert_array_equal(raw, pre)
    # spec, when passed alongside a bank, must agree with the bank's
    np.testing.assert_array_equal(
        pre, fir_apply(x, bank, spec, backend=backend, block=128, bc=2))
    with pytest.raises(ValueError, match="match"):
        fir_apply(x, bank, MulSpec("bbm0", 16, 11), backend=backend)


def test_precoded_bank_take_is_a_view_not_a_redecode(monkeypatch):
    import repro.dsp.fir as fir_mod
    spec = MulSpec("bbm0", 12, 7)
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    bank = PrecodedBank(banks, spec)
    calls = []
    monkeypatch.setattr(fir_mod, "booth_precode",
                        lambda *a, **k: calls.append(1))
    taken = bank.take([1, 0, 1])
    assert not calls                     # gather only, never re-decode
    assert taken.num_banks == 3 and taken.taps == bank.taps
    np.testing.assert_array_equal(taken.hq, bank.hq[[1, 0, 1]])
    np.testing.assert_array_equal(np.asarray(taken.planes[0]),
                                  np.asarray(bank.planes[0])[:, [1, 0, 1]])


def test_sharded_filterbank_precoded_planes_path():
    from repro.parallel import precode_filterbank, sharded_filterbank
    from repro.kernels.ref import fir_bank_ref
    wl, vbl, kind = 12, 9, 1
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(RNG.integers(0, 1 << wl, (4, 256)), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, (4, 31)), jnp.int32)
    ref = fir_bank_ref(x, h, wl=wl, vbl=vbl, kind=kind)
    planes = precode_filterbank(h, wl=wl)
    got = sharded_filterbank(x, h, mesh, wl=wl, vbl=vbl, kind=kind,
                             use_kernel=True, bt=128, h_planes=planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------- serve level
def test_filterbank_engine_precodes_banks_once(monkeypatch):
    """The engine builds its PrecodedBank at construction and never decodes
    again across flush rounds; outputs match the direct datapath."""
    import repro.dsp.fir as fir_mod
    from repro.serve import FilterbankEngine
    real = fir_mod.booth_precode
    calls = []

    def counting(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(fir_mod, "booth_precode", counting)
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    spec = MulSpec("bbm0", 16, 13)
    eng = FilterbankEngine(banks, spec, backend="pallas-interpret",
                           max_channels=4, block=128)
    assert len(calls) == 1               # decode phase: once, at construction
    sigs = [RNG.standard_normal(n) for n in (300, 200, 300)]
    rids = [eng.submit(s, bank=i % 2) for i, s in enumerate(sigs)]
    out1 = eng.flush()
    rids2 = [eng.submit(s, bank=1) for s in sigs[:2]]
    out2 = eng.flush()
    assert len(calls) == 1               # two flush rounds, zero re-decodes
    assert sorted(out1) == sorted(rids) and sorted(out2) == sorted(rids2)
    # the cached-bank results equal the one-shot datapath, bit for bit
    solo = fir_apply(sigs[1], banks[1], spec, backend="pallas-interpret",
                     block=128)
    np.testing.assert_array_equal(out1[rids[1]], solo)
