"""Dry-run machinery + roofline analysis units (no 512-device compile here;
the full sweep runs via `python -m repro.launch.dryrun --all`)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.hlo_analysis import (analyze_hlo, split_computations,
                                     trip_count)
from benchmarks.roofline import model_flops, param_count


def test_param_counts_sane():
    """Headline parameter counts should land near the model names."""
    targets = {
        "deepseek-v3-671b": (600e9, 750e9),
        "grok-1-314b": (280e9, 360e9),
        "qwen1.5-110b": (95e9, 125e9),
        "yi-34b": (30e9, 40e9),
        "llama3.2-3b": (2.5e9, 4.5e9),
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "chameleon-34b": (30e9, 40e9),
        "mamba2-370m": (0.25e9, 0.5e9),
        "zamba2-2.7b": (2.0e9, 3.5e9),
        "whisper-base": (0.05e9, 0.12e9),
    }
    for arch, (lo, hi) in targets.items():
        n = param_count(arch)["total"]
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in [{lo / 1e9}, {hi / 1e9}]"


def test_moe_active_params_much_smaller():
    pc = param_count("deepseek-v3-671b")
    assert pc["active"] < 0.12 * pc["total"]      # ~37B of 671B


def test_model_flops_scaling():
    f_train = model_flops("yi-34b", "train_4k")
    f_prefill = model_flops("yi-34b", "prefill_32k")
    f_decode = model_flops("yi-34b", "decode_32k")
    assert f_train > f_prefill > f_decode
    # train: 6ND with 1M tokens
    n = param_count("yi-34b")["active"]
    assert f_train == pytest.approx(6 * n * 4096 * 256)


SYNTH_HLO = """
HloModule test, is_scheduled=true

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %gte0 = s32[] get-tuple-element(%arg), index=0
  %gte1 = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %d = f32[8,8]{1,0} dot(%gte1, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %next = s32[] add(%gte0, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%next, %d)
}

%cond (arg2: (s32[], f32[8,8])) -> pred[] {
  %gte = s32[] get-tuple-element(%arg2), index=0
  %lim = s32[] constant(10)
  ROOT %cmp = pred[] compare(%gte, %lim), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%zero, %x)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_analyze_synthetic_while():
    res = analyze_hlo(SYNTH_HLO)
    # 10 iterations x (2 * 8*8*8) flops
    assert res["flops"] == pytest.approx(10 * 2 * 8 * 8 * 8)


def test_trip_count_from_condition():
    comps = split_computations(SYNTH_HLO)
    assert "cond" in comps
    assert trip_count(comps["cond"]) == 10


def test_analyzer_matches_known_scan():
    """End-to-end against a real compile (single host device)."""
    script = r"""
import jax, jax.numpy as jnp, sys, json
sys.path.insert(0, ".")
from benchmarks.hlo_analysis import analyze_hlo
N, L = 64, 7
def f(x, ws):
    def body(c, w):
        return jnp.tanh(c @ w), None
    y, _ = jax.lax.scan(body, x, ws)
    return y.sum()
comp = jax.jit(f).lower(jax.ShapeDtypeStruct((N, N), jnp.float32),
                        jax.ShapeDtypeStruct((L, N, N), jnp.float32)).compile()
res = analyze_hlo(comp.as_text())
print(json.dumps({"flops": res["flops"], "expect": 2.0 * N**3 * L}))
"""
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] == pytest.approx(res["expect"], rel=0.01)


def test_eligibility_rules():
    from repro.launch import dryrun  # noqa: F401  (import only; no jax use)
    # long_500k only for sub-quadratic archs
    from repro.configs import get_arch
    assert get_arch("mamba2-370m").sub_quadratic
    assert get_arch("zamba2-2.7b").sub_quadratic
    assert not get_arch("yi-34b").sub_quadratic
    assert not get_arch("deepseek-v3-671b").sub_quadratic


def test_dryrun_results_if_present():
    """When the sweep has run, every recorded cell must be ok=True."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("dry-run sweep not yet executed")
    data = json.load(open(path))
    bad = [f"{r['arch']}/{r['shape']}/{r.get('mesh')}"
           for r in data if not r.get("ok")]
    assert not bad, f"failed dry-run cells: {bad}"
    # coverage: every eligible (arch x shape) on the single-pod mesh
    from repro.configs import ARCH_NAMES, SHAPES, get_arch
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in data if r.get("ok")}
    missing = []
    for a in ARCH_NAMES:
        for s in SHAPES:
            if s == "long_500k" and not get_arch(a).sub_quadratic:
                continue
            if (a, s, "16x16") not in seen:
                missing.append(f"{a}/{s}")
    assert not missing, f"missing single-pod cells: {missing}"
