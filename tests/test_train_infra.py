"""Training infrastructure: optimizer, checkpoint/restore, fault-tolerant
loop (with injected failures), data determinism, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.data.pipeline import DataConfig, batches, global_batch, host_shard
from repro.launch.mesh import make_host_mesh
from repro.models import ModelRuntime
from repro.parallel.compress import allreduce_ref, compress_decompress
from repro.train import checkpoint as ckpt
from repro.train.loop import LoopConfig, StragglerMonitor, train_loop
from repro.train.optimizer import (OptConfig, apply_updates, global_norm,
                                   init_opt, warmup_cosine)
from repro.train.trainstep import TrainConfig, init_train_state, \
    make_train_step


# ----------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                    total_steps=100)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adafactor_reduces_quadratic():
    cfg = OptConfig(kind="adafactor", lr=0.1, weight_decay=0.0,
                    warmup_steps=0, total_steps=200)
    params = {"w": jnp.ones((4, 3)) * 2.0}
    state = init_opt(params, cfg)
    for _ in range(80):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_warmup_cosine_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    s = warmup_cosine(cfg)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1.0)
    assert float(s(jnp.int32(100))) < 0.2


def test_grad_clip():
    from repro.train.optimizer import clip_by_global_norm
    g = {"a": jnp.ones(100) * 10}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(gn) == pytest.approx(100.0)


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    ckpt.save(tree, 7, str(tmp_path))
    out, step = ckpt.restore(tree, str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_picks_latest_complete(tmp_path):
    tree = {"x": jnp.zeros(3)}
    ckpt.save(tree, 1, str(tmp_path))
    ckpt.save({"x": jnp.ones(3)}, 5, str(tmp_path))
    # fake a torn write at step 9
    os.makedirs(tmp_path / "step_000000009")
    out, step = ckpt.restore(tree, str(tmp_path))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(3))


def test_checkpoint_gc(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tree, s, str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    remaining = sorted(os.listdir(tmp_path))
    assert len([d for d in remaining if d.startswith("step_")]) == 2


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore device_puts against a different sharding than written."""
    mesh = make_host_mesh(1, 1)
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(tree, 3, str(tmp_path))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    out, _ = ckpt.restore(tree, str(tmp_path), shardings=sh)
    assert out["w"].sharding == sh["w"]


# ---------------------------------------------------------------------- data
def test_data_deterministic_and_elastic():
    dc = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    a1, b1 = global_batch(dc, 5)
    a2, b2 = global_batch(dc, 5)
    np.testing.assert_array_equal(a1, a2)
    # host sharding slices the same global batch
    h0 = host_shard(a1, 0, 2)
    h1 = host_shard(a1, 1, 2)
    np.testing.assert_array_equal(np.concatenate([h0, h1]), a1)
    # labels are next-token shifted
    np.testing.assert_array_equal(a1[:, 1:], b1[:, :-1])


# ------------------------------------------------------------- train + loop
@pytest.fixture(scope="module")
def tiny_setup():
    """step_fn donates params/opt, so each test gets a fresh copy."""
    cfg = reduced(get_arch("qwen2-0.5b"))
    rt = ModelRuntime.build(cfg)
    mesh = make_host_mesh(1, 1)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, total_steps=100))
    step_fn = make_train_step(cfg, rt, tc, mesh, global_batch=4)
    params0, opt0 = init_train_state(cfg, tc, mesh, jax.random.key(0))

    class Setup:
        def fresh(self):
            return (jax.tree.map(jnp.copy, params0),
                    jax.tree.map(jnp.copy, opt0))
    s = Setup()
    s.cfg, s.step_fn = cfg, step_fn
    return s


def test_loss_decreases(tiny_setup, tmp_path):
    cfg, step_fn = tiny_setup.cfg, tiny_setup.step_fn
    params, opt = tiny_setup.fresh()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    lc = LoopConfig(total_steps=12, ckpt_every=0, ckpt_dir=str(tmp_path),
                    log_every=100)

    def data_iter(start):
        for t, l, s in batches(dc, start):
            yield jnp.asarray(t), jnp.asarray(l), s

    _, _, hist = train_loop(step_fn, params, opt, data_iter, lc,
                            rng=jax.random.key(1), log_fn=lambda s: None)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first   # synthetic data has learnable structure


def test_loop_recovers_from_injected_failure(tiny_setup, tmp_path):
    """A step that raises gets retried from the last checkpoint."""
    cfg, step_fn = tiny_setup.cfg, tiny_setup.step_fn
    params, opt = tiny_setup.fresh()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    lc = LoopConfig(total_steps=10, ckpt_every=3, ckpt_dir=str(tmp_path),
                    log_every=100, max_retries=2)
    fail_at = {"step": 7, "fired": False}

    def failure_hook(step):
        if step == fail_at["step"] and not fail_at["fired"]:
            fail_at["fired"] = True
            raise RuntimeError("injected node failure")

    def data_iter(start):
        for t, l, s in batches(dc, start):
            yield jnp.asarray(t), jnp.asarray(l), s

    _, _, hist = train_loop(step_fn, params, opt, data_iter, lc,
                            rng=jax.random.key(1),
                            failure_hook=failure_hook,
                            log_fn=lambda s: None)
    assert fail_at["fired"]
    steps_seen = [h["step"] for h in hist]
    assert steps_seen[-1] == 9                  # completed despite failure
    # replayed steps appear twice (restore rewound to checkpoint at 6)
    assert steps_seen.count(7) >= 1


def test_loop_resumes_from_checkpoint(tiny_setup, tmp_path):
    cfg, step_fn = tiny_setup.cfg, tiny_setup.step_fn
    params, opt = tiny_setup.fresh()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)

    def data_iter(start):
        for t, l, s in batches(dc, start):
            yield jnp.asarray(t), jnp.asarray(l), s

    lc1 = LoopConfig(total_steps=6, ckpt_every=2, ckpt_dir=str(tmp_path),
                     log_every=100)
    train_loop(step_fn, params, opt, data_iter, lc1, rng=jax.random.key(1),
               log_fn=lambda s: None)
    lc2 = LoopConfig(total_steps=9, ckpt_every=100, ckpt_dir=str(tmp_path),
                     log_every=100)
    _, _, hist2 = train_loop(step_fn, params, opt, data_iter, lc2,
                             rng=jax.random.key(1), log_fn=lambda s: None)
    assert hist2[0]["step"] == 6                 # resumed, not restarted


def test_straggler_monitor():
    m = StragglerMonitor(alpha=0.2, z_thresh=2.0)
    rng = np.random.default_rng(0)
    for _ in range(30):
        m.observe(0.1 + rng.normal() * 1e-3)
    assert m.observe(1.0)                        # 10x step flagged
    assert not m.observe(0.1)


# ------------------------------------------------------ gradient compression
def test_compress_bf16_roundtrip():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    out = compress_decompress(g, "bf16")
    assert float(jnp.max(jnp.abs(out - g))) < 0.01


def test_compress_int8_blockwise():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000) * 5,
                    jnp.float32)
    out = compress_decompress(g, "int8")
    rel = float(jnp.linalg.norm(out - g) / jnp.linalg.norm(g))
    assert rel < 0.01                            # 127-level blockwise
    # error feedback closes the gap over repeated steps
    e = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(8):
        sent = compress_decompress(g + e, "int8")
        e = g + e - sent
        acc = acc + sent
    np.testing.assert_allclose(np.asarray(acc / 8), np.asarray(g), atol=0.02)


def test_allreduce_ref_matches_mean():
    gs = jnp.asarray(np.random.default_rng(1).standard_normal((4, 64)),
                     jnp.float32)
    out = allreduce_ref(gs, "bf16")
    np.testing.assert_allclose(np.asarray(out), np.asarray(gs.mean(0)),
                               atol=0.02)
