"""Oracle/calibration suite for the model-scale bit-exact amm datapath.

``amm_dense`` mode="bitexact" now lowers the Broken-Booth datapath to
dense contractions (``kernels.bbm_matmul_scaled``: exact-dot + low-bit
correction, int32-exact K-chunks).  The retained scalar outer-product
path lives on as the oracle (``kernels.ref.amm_dense_ref``); this suite
holds the two to *bitwise* equality across wl x vbl x multiplier family
x apply_to, at envelope-boundary operands, through the per-parameter
digit-plane cache, and at the LM configs — and proves the structural
claim (no (..., K, N) intermediate) on the jaxpr itself.

It also ties the two amm modes to each other for the first time: the
per-product error moments the "noise" mode injects must match the
empirical error of the "bitexact" closed forms, and the fused Pallas
quant_matmul path (AmmConfig.use_pallas) must agree with the plain noise
path numerically and draw calibrated noise.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import AmmConfig, get_arch, reduced
from repro.core.booth import to_signed
from repro.core.multipliers import MulSpec, mul as core_mul
from repro.core.noise import make_noise_model
from repro.kernels.booth_rows import amm_chunk_len
from repro.kernels.ref import amm_approx_ref, amm_dense_ref, amm_quantize
from repro.models.common import AmmRuntime, amm_dense

RNG = np.random.default_rng(11)

# (mul, wl, vbl): Booth family across word lengths (dot-form datapath),
# the exact multiplier (vbl = 0, per-product chunks at wl = 16), a
# multi-chunk point ((16, 3): single-digit chunk length, so modest K
# already splits), and the sign-magnitude families that keep the scalar
# path
SWEEP = [("bbm0", 8, 5), ("bbm1", 8, 7), ("bbm0", 12, 7), ("bbm1", 12, 11),
         ("bbm0", 16, 13), ("bbm1", 16, 15), ("bbm0", 16, 3),
         ("booth", 12, 0), ("booth", 16, 0), ("bam", 8, 4),
         ("kulkarni", 8, 3)]


def _rt(mul, wl, vbl, apply_to="mlp", mode="bitexact", use_pallas=False):
    return AmmRuntime.build(AmmConfig(mode=mode, mul=mul, wl=wl, param=vbl,
                                      apply_to=apply_to,
                                      use_pallas=use_pallas))


def _operands(m=7, k=24, n=9, seed=3):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k))
    w = rng.standard_normal((k, n))
    # boundary rows/cols: entries that quantize to the full-scale codes
    # +/-lim (the quantizer's envelope edge) in every contraction
    x[0, :] = np.abs(x).max() * 1.5
    x[1, :] = -np.abs(x).max()
    w[:, 0] = np.abs(w).max() * 1.5
    w[:, 1] = -np.abs(w).max()
    return jnp.asarray(x, jnp.float32), jnp.asarray(w, jnp.float32)


# ------------------------------------------------- dot form vs the oracle
@pytest.mark.parametrize("mul,wl,vbl", SWEEP)
def test_amm_dense_matches_oracle(mul, wl, vbl):
    x, w = _operands()
    rt = _rt(mul, wl, vbl)
    got = np.asarray(amm_dense(x, w, rt))
    ref = np.asarray(amm_dense_ref(x, w, MulSpec(mul, wl, vbl)))
    np.testing.assert_array_equal(got, ref)


def test_apply_to_is_model_level_routing_only():
    """``AmmConfig.apply_to`` selects *which* model matmuls are
    approximated; it is not (and must not become) an input to the
    per-matmul datapath.  apply_to="all" now routes attention's QK^T/PV
    through ``amm_dot`` as well (tests/test_amm_attention.py owns that
    axis), but ``amm_dense`` itself — the weight-side datapath — must
    stay apply_to-independent, which this pins."""
    x, w = _operands()
    for mul, wl, vbl in (("bbm0", 16, 13), ("bam", 8, 4)):
        a = np.asarray(amm_dense(x, w, _rt(mul, wl, vbl, "mlp")))
        b = np.asarray(amm_dense(x, w, _rt(mul, wl, vbl, "all")))
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(
            a, np.asarray(amm_dense_ref(x, w, MulSpec(mul, wl, vbl))))


@pytest.mark.parametrize("k_extra", [0, 1, 7])
def test_amm_dense_chunk_boundary(k_extra):
    """K at and just past the int32-exact chunk length, full-scale codes.

    (16, 3) has a single-digit chunk length (the scaled envelope
    ``2^(2wl-1-vbl)`` leaves only ``2^vbl`` products of headroom at
    wl = 16): K = chunk is the largest single-chunk accumulation,
    K = chunk + 1/chunk + 7 force the cross-chunk float32 combine — the
    partials sit at the accumulator envelope and must still match the
    oracle bit for bit.
    """
    wl, vbl = 16, 3
    chunk = amm_chunk_len(wl, vbl)
    assert 1 < chunk < 16          # genuinely exercises the chunked path
    k = chunk + k_extra
    rng = np.random.default_rng(5)
    # constant-magnitude operands quantize to +/-lim everywhere: every
    # partial product sits at the scaled envelope edge
    x = jnp.asarray(np.where(rng.random((5, k)) < 0.5, -1.0, 1.0),
                    jnp.float32)
    w = jnp.asarray(np.where(rng.random((k, 6)) < 0.5, -1.0, 1.0),
                    jnp.float32)
    rt = _rt("bbm0", wl, vbl)
    np.testing.assert_array_equal(
        np.asarray(amm_dense(x, w, rt)),
        np.asarray(amm_dense_ref(x, w, MulSpec("bbm0", wl, vbl))))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_amm_dense_batched_inputs(dtype):
    """(B, S, K) activations — the model's actual calling convention."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((2, 5, 16)), dtype)
    w = jnp.asarray(rng.standard_normal((16, 11)), jnp.float32)
    rt = _rt("bbm0", 12, 7)
    got = amm_dense(x, w, rt)
    ref = amm_dense_ref(x, w, MulSpec("bbm0", 12, 7))
    assert got.dtype == (x @ w).dtype    # STE rides the exact product
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(ref, np.float32))


def test_amm_dense_bf16_fullscale_no_wraparound():
    """bf16 activations at full scale must not wrap to the negative code.

    The wl = 16 clip bound 32767 is unrepresentable in bf16 (nearest is
    32768); a quantizer that rounds/clips in the input dtype emits code
    +32768, which the Booth decode masks to the wl-bit field and
    reinterprets as -32768 — flipping the sign of the largest activation.
    The oracle shares the quantizer, so bitwise equality alone cannot see
    it: this pins the output against the *exact* product instead.
    ``lm_apply`` feeds the MLPs bf16, so this is the serving dtype.
    """
    wl, vbl = 16, 13
    x = jnp.ones((4, 16), jnp.bfloat16)          # quantizes to +lim each
    w = jnp.asarray(np.full((16, 6), 0.5), jnp.float32)
    got = np.asarray(amm_dense(x, w, _rt("bbm0", wl, vbl)), np.float64)
    exact = np.asarray(jnp.asarray(x, jnp.float32) @ w, np.float64)
    # truncation removes < K * R * 2^vbl * s_x * s_w ~ 1e-3 here; a
    # wrapped code would be off by ~2 * exact
    assert np.all(got > 0)
    np.testing.assert_allclose(got, exact, rtol=5e-3)
    # and the codes themselves stay inside the signed wl-bit field
    codes, _ = amm_quantize(x, wl)
    assert int(jnp.max(codes)) <= 2 ** (wl - 1) - 1
    assert int(jnp.min(codes)) >= -(2 ** (wl - 1))


@given(seed=st.integers(0, 1000), m=st.integers(1, 9), k=st.integers(1, 40),
       n=st.integers(1, 9), idx=st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_prop_amm_dense_matches_oracle(seed, m, k, n, idx):
    mul, wl, vbl = [("bbm0", 16, 13), ("bbm1", 16, 15), ("bbm0", 12, 7),
                    ("bbm1", 8, 5), ("bbm0", 16, 3), ("booth", 12, 0)][idx]
    rng = np.random.default_rng(seed)
    scale = 10.0 ** rng.integers(-3, 4)
    x = jnp.asarray(rng.standard_normal((m, k)) * scale, jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * scale, jnp.float32)
    got = np.asarray(amm_dense(x, w, _rt(mul, wl, vbl)))
    ref = np.asarray(amm_dense_ref(x, w, MulSpec(mul, wl, vbl)))
    np.testing.assert_array_equal(got, ref)


# --------------------------------------------------- structural guarantee
def test_amm_dense_never_materializes_kn():
    """No intermediate in the whole jaxpr reaches M*K*N elements.

    The oracle's defining memory cliff is the (..., K, N) scalar product
    grid; the dot-form datapath must not have one anywhere — including
    inside nested pjit/scan jaxprs.  (The planes are (wl//2, K, N); M is
    chosen > wl//2 so they stay under the bar too.)
    """
    m, k, n = 31, 48, 29
    x = jnp.zeros((m, k), jnp.float32)
    w = jnp.zeros((k, n), jnp.float32)

    def collect(jaxpr, out):
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                if hasattr(v.aval, "shape"):
                    out.append(tuple(v.aval.shape))
            for p in eqn.params.values():
                recurse(p, out)

    def recurse(p, out):
        if hasattr(p, "eqns"):                 # Jaxpr
            collect(p, out)
        elif hasattr(p, "jaxpr"):              # ClosedJaxpr
            recurse(p.jaxpr, out)
        elif isinstance(p, (list, tuple)):
            for q in p:
                recurse(q, out)

    for vbl in (13, 3):                        # single- and multi-chunk
        rt = _rt("bbm0", 16, vbl)
        jaxpr = jax.make_jaxpr(lambda a, b: amm_dense(a, b, rt))(x, w)
        shapes = []
        collect(jaxpr.jaxpr, shapes)
        sizes = [int(np.prod(s)) for s in shapes if s]
        assert sizes, "expected a non-trivial jaxpr"
        assert max(sizes) < m * k * n, (
            f"vbl={vbl}: intermediate of {max(sizes)} elements >= "
            f"M*K*N = {m * k * n}")


def test_amm_gradients_are_ste_on_dot_path():
    """The rewrite must keep the straight-through estimator contract."""
    rt = _rt("bbm0", 16, 13)
    x = jnp.asarray(RNG.standard_normal((4, 8)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((8, 4)), jnp.float32)
    g1 = jax.grad(lambda ww: jnp.sum(amm_dense(x, ww, rt)))(w)
    g2 = jax.grad(lambda ww: jnp.sum(x @ ww))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


# ------------------------------------------------- digit-plane cache path
def test_amm_dense_planes_bit_identical():
    x, w = _operands()
    for mul, wl, vbl in (("bbm0", 16, 13), ("bbm1", 12, 11), ("bbm0", 16, 3)):
        rt = _rt(mul, wl, vbl)
        planes = rt.precode(w)
        assert planes is not None
        assert planes["mag"].shape == (wl // 2,) + w.shape
        np.testing.assert_array_equal(
            np.asarray(amm_dense(x, w, rt)),
            np.asarray(amm_dense(x, w, rt, planes=planes)))


def test_amm_precode_none_when_not_cacheable():
    x, w = _operands()
    assert _rt("bam", 8, 4).precode(w) is None
    assert _rt("bbm0", 16, 13, mode="noise").precode(w) is None
    assert _rt("bbm0", 16, 13, mode="off").precode(w) is None


def test_lm_apply_planes_bit_identical():
    """End to end through the reduced qwen2: cached planes == inline."""
    from repro.models import ModelRuntime, lm_amm_planes, lm_apply, lm_init
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, amm=AmmConfig(mode="bitexact", mul="bbm0",
                                                 wl=16, param=13))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    planes = lm_amm_planes(cfg, rt.amm, params)
    assert planes is not None
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 12)), jnp.int32)
    l0, _, _ = lm_apply(params, cfg, rt, toks, rng=jax.random.key(2))
    l1, _, _ = lm_apply(params, cfg, rt, toks, rng=jax.random.key(2),
                        amm_planes=planes)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))


# ------------------------------------- noise model <-> bitexact datapath
@pytest.mark.parametrize("mul,wl,vbl", [("bbm0", 12, 9), ("bbm1", 10, 7)])
def test_product_error_moments_match_noise_model(mul, wl, vbl):
    """Empirical per-product BBM error == the injected (mu, sigma).

    The first direct tie between the two amm modes: the moments
    ``make_noise_model`` injects in mode="noise" must be the moments the
    mode="bitexact" closed forms actually produce over uniform operands.
    """
    spec = MulSpec(mul, wl, vbl)
    nm = make_noise_model(spec, sample=1 << 18)
    rng = np.random.default_rng(17)
    a = jnp.asarray(rng.integers(0, 1 << wl, 1 << 18), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1 << wl, 1 << 18), jnp.int32)
    approx = np.asarray(core_mul(spec)(a, b), np.int64)
    exact = (np.asarray(to_signed(a, wl), np.int64)
             * np.asarray(to_signed(b, wl), np.int64))
    err = (approx - exact).astype(np.float64)
    assert err.mean() == pytest.approx(nm.mean, rel=0.05)
    assert err.std() == pytest.approx(np.sqrt(nm.var), rel=0.05)


# ------------------------------------------- fused Pallas noise fast path
def test_amm_noise_pallas_noiseless_bitwise():
    """use_pallas with an exact spec == the plain quantized matmul.

    wl = 8 keeps every partial sum inside float32's exact-integer range,
    so the kernel's tiled accumulation and the single jnp.dot must agree
    bitwise, quantization included.
    """
    x, w = _operands(m=16, k=32, n=8)
    y_pl = amm_dense(x, w, _rt("booth", 8, 0, mode="noise", use_pallas=True))
    y_np = amm_dense(x, w, _rt("booth", 8, 0, mode="noise"))
    np.testing.assert_array_equal(np.asarray(y_pl), np.asarray(y_np))


def test_amm_noise_pallas_moments():
    """Fused in-kernel noise carries the calibrated (mu, sigma)."""
    rt = _rt("bbm0", 12, 9, mode="noise", use_pallas=True)
    assert rt.sigma > 0
    rng = np.random.default_rng(23)
    x = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32)
    base = np.asarray(amm_dense(x, w, _rt("booth", 12, 0, mode="noise",
                                          use_pallas=True)))
    # same quantization grid: eps = (noisy - base) / (s_x * s_w)
    lim = float(2 ** 11 - 1)
    s_x = float(jnp.max(jnp.abs(x))) / lim
    s_w = float(jnp.max(jnp.abs(w))) / lim
    noisy = np.asarray(amm_dense(x, w, rt, key=jax.random.key(0)))
    eps = (noisy - base) / (s_x * s_w)
    k = x.shape[-1]
    assert eps.mean() == pytest.approx(rt.mu * k, rel=0.1)
    assert eps.std() == pytest.approx(rt.sigma * np.sqrt(k), rel=0.1)


def test_amm_noise_pallas_keyed():
    """Same key -> same draw; different key -> different draw."""
    x, w = _operands(m=8, k=16, n=8)
    rt = _rt("bbm0", 12, 9, mode="noise", use_pallas=True)
    a = np.asarray(amm_dense(x, w, rt, key=jax.random.key(4)))
    b = np.asarray(amm_dense(x, w, rt, key=jax.random.key(4)))
    c = np.asarray(amm_dense(x, w, rt, key=jax.random.key(5)))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
