"""Runtime guards: finite/budget monitors and checkify-wired envelopes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.guards import (GuardConfig, GuardReport, checkify_call,
                               code_range_check, finite_rows, guard_rows,
                               scaled_bound_check)


def test_finite_rows_per_row_granularity():
    y = np.ones((4, 8), np.float32)
    y[1, 3] = np.nan
    y[3, 0] = np.inf
    np.testing.assert_array_equal(finite_rows(y),
                                  [True, False, True, False])


def test_guard_rows_finite_trip():
    y = np.ones((3, 4), np.float32)
    y[2] = np.nan
    rep = guard_rows(y, GuardConfig())
    assert not rep.ok and rep.tripped == ("finite",)
    np.testing.assert_array_equal(rep.row_ok, [True, True, False])
    assert rep.nonfinite == 4


def test_guard_rows_budget_trip_only_on_audited_rows():
    cfg = GuardConfig(budget_abs=0.5, budget_every=1)
    y = np.zeros((3, 4), np.float32)
    exact = np.stack([np.zeros(4), np.ones(4), np.full(4, 0.4)]
                     ).astype(np.float32)
    rep = guard_rows(y, cfg, y_exact=exact)
    assert rep.tripped == ("budget",)
    np.testing.assert_array_equal(rep.row_ok, [True, False, True])
    assert rep.budget_err == pytest.approx(1.0)
    # no reference passed -> no budget check, clean report
    assert guard_rows(y, cfg).ok


def test_guard_rows_clean():
    rep = guard_rows(np.ones((2, 2)), GuardConfig())
    assert rep.ok and rep.tripped == () and rep.row_ok.all()


def test_budget_active_requires_both_knobs():
    assert not GuardConfig().budget_active
    assert not GuardConfig(budget_abs=0.1).budget_active
    assert not GuardConfig(budget_every=4).budget_active
    assert GuardConfig(budget_abs=0.1, budget_every=4).budget_active


def test_report_trip_dedups():
    rep = GuardReport()
    rep.trip("finite")
    rep.trip("finite")
    rep.trip("budget")
    assert rep.tripped == ("finite", "budget") and not rep.ok


def test_code_range_check_survives_jit():
    """The point of checkify wiring: the check runs *inside* a jitted
    function and still raises host-side with its message."""
    def f(c):
        code_range_check(c, 8)
        return c * 2

    out = checkify_call(f, jnp.arange(-128, 128))
    assert out.shape == (256,)
    with pytest.raises(Exception, match="8-bit envelope"):
        checkify_call(f, jnp.array([200]))
    with pytest.raises(Exception, match="8-bit envelope"):
        checkify_call(f, jnp.array([-129]))


def test_scaled_bound_check_trips_past_bound():
    def g(a):
        scaled_bound_check(a, 100)
        return a + 1

    np.testing.assert_array_equal(
        np.asarray(checkify_call(g, jnp.array([100], jnp.int32))), [101])
    with pytest.raises(Exception, match="int32 envelope"):
        checkify_call(g, jnp.array([-101], jnp.int32))


def test_checkify_call_is_jitted_and_transparent():
    """No tripped check -> the wrapped output equals the plain call."""
    def f(x):
        code_range_check(x, 16, what="codes")
        return jnp.cumsum(x)

    x = jnp.arange(10)
    np.testing.assert_array_equal(np.asarray(checkify_call(f, x)),
                                  np.asarray(jnp.cumsum(x)))
