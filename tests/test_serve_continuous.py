"""Serving conformance suite for continuous batching + the int-code cache.

The contract under test: with attention-side amm routing
(``apply_to="attn"`` — per-(slot, head) quantization scales) every
request's token stream is *bitwise* the stream it would produce running
solo, no matter how admissions, evictions and failures interleave around
it.  ``kv_codes=True`` strengthens this to the cache representation
itself: codes freeze at write time, so later arrivals cannot move a
resident's quantization grid (the scale-drift fix pinned numerically in
tests/test_amm_attention.py).

Covers: random admission interleavings vs solo runs (seeded numpy always;
a hypothesis property variant when the real package is installed), FIFO
admission, prefill/decode disaggregation (a resident gains exactly one
token per step while long prompts queue), slot recycling after mid-stream
poison failure, deadline eviction, the int8 code-cache memory contract,
and the ``Scheduler`` constructor's kv_codes validation.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
from repro.configs import get_arch, reduced
from repro.configs.base import AmmConfig
from repro.core.guards import GuardConfig
from repro.models import ModelRuntime, lm_init
from repro.serve.engine import Request, Scheduler
from repro.serve.kv_cache import KV_BLOCK, memory_report

WL, VBL = 8, 5
SLOTS = 3
MAX_LEN = 2 * KV_BLOCK


@pytest.fixture(scope="module")
def lm():
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode="bitexact", mul="bbm0", wl=WL, param=VBL,
                           apply_to="attn"))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    return cfg, rt, params


def _sched(lm, slots=SLOTS, **kw):
    cfg, rt, params = lm
    kw.setdefault("kv_codes", True)
    return Scheduler(cfg, rt, params, slots, MAX_LEN, continuous=True, **kw)


def _drain(sched, cap=300):
    steps = 0
    while sched.step():
        steps += 1
        assert steps < cap, "scheduler failed to terminate"
    return steps


def _solo_stream(lm, prompt, max_new, *, kv_codes=True):
    """The reference stream: same scheduler, same slot count, one request."""
    sched = _sched(lm, kv_codes=kv_codes)
    req = Request(rid=0, prompt=list(prompt), max_new=max_new)
    sched.submit(req)
    _drain(sched)
    assert req.done and req.error is None
    return req.out


def _run_interleaved(lm, arrivals, *, kv_codes=True):
    """Drive one continuous scheduler through an arrival schedule.

    ``arrivals``: [(step, prompt, max_new)] sorted by step; requests are
    submitted right before the scheduler step they arrive at.
    """
    sched = _sched(lm, kv_codes=kv_codes)
    reqs = []
    t, idx = 0, 0
    while True:
        while idx < len(arrivals) and arrivals[idx][0] <= t:
            _, prompt, max_new = arrivals[idx]
            r = Request(rid=idx, prompt=list(prompt), max_new=max_new)
            reqs.append(r)
            sched.submit(r)
            idx += 1
        n = sched.step()
        t += 1
        if n == 0 and idx >= len(arrivals) and not sched.queue:
            break
        assert t < 500, "interleaved run failed to terminate"
    return sched, reqs


def _random_arrivals(rng, vocab, n=4):
    arrivals = []
    step = 0
    for _ in range(n):
        step += int(rng.integers(0, 3))
        plen = int(rng.integers(0, 9))          # 0 = empty prompt
        prompt = rng.integers(1, vocab, plen).tolist()
        arrivals.append((step, prompt, int(rng.integers(1, 5))))
    return arrivals


# ------------------------------------------------ solo-vs-batched bitwise
def _assert_conformant(lm, seed, *, kv_codes):
    cfg = lm[0]
    rng = np.random.default_rng(seed)
    arrivals = _random_arrivals(rng, cfg.vocab)
    sched, reqs = _run_interleaved(lm, arrivals, kv_codes=kv_codes)
    assert sched.stats["completed"] == len(reqs)
    solo_memo = {}
    for r, (_, prompt, max_new) in zip(reqs, arrivals):
        assert r.done and r.error is None
        key = (tuple(prompt), max_new)
        if key not in solo_memo:
            solo_memo[key] = _solo_stream(lm, prompt, max_new,
                                          kv_codes=kv_codes)
        assert r.out == solo_memo[key], (
            f"request {r.rid} (seed {seed}): batched stream {r.out} != "
            f"solo stream {solo_memo[key]}")


def test_streams_bitwise_equal_to_solo_runs_code_cache(lm):
    """Random interleavings, int-code cache: every stream == its solo run."""
    for seed in (7, 23):
        _assert_conformant(lm, seed, kv_codes=True)


def test_streams_bitwise_equal_to_solo_runs_float_cache(lm):
    """Same contract on the float cache — continuous batching alone must
    not change anyone's bits either (per-slot requantize scales)."""
    _assert_conformant(lm, 11, kv_codes=False)


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_streams_conformant_property(lm, seed):
    """Hypothesis-driven interleavings (skips when hypothesis is absent:
    the seeded trials above keep the contract pinned in CI)."""
    _assert_conformant(lm, seed, kv_codes=True)


# ------------------------------------------------------ scheduling policy
def test_fifo_admission_under_slot_contention(lm):
    """One slot, three requests: admission and completion follow
    submission order, one prefill per step."""
    sched = _sched(lm, slots=1)
    reqs = [Request(rid=i, prompt=[i + 1], max_new=2) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    done_order, first_tok_order = [], []
    while sched.step() or sched.queue:
        for r in reqs:
            if r.out and r.rid not in first_tok_order:
                first_tok_order.append(r.rid)
            if r.done and r.rid not in done_order:
                done_order.append(r.rid)
    assert first_tok_order == [0, 1, 2]
    assert done_order == [0, 1, 2]


def test_resident_decodes_every_step_while_prompts_queue(lm):
    """Prefill/decode disaggregation: with a queue of long prompts and
    ``max_prefills_per_step=1``, a resident request still gains exactly
    one token every scheduler step — admissions cost it wall-clock only,
    never a decode turn."""
    sched = _sched(lm)
    resident = Request(rid=0, prompt=[1, 2], max_new=12)
    sched.submit(resident)
    sched.step()                      # prefill emits token 1, decode adds 1
    assert len(resident.out) == 2
    long = list(range(1, 13))
    for i in range(1, 4):
        sched.submit(Request(rid=i, prompt=long, max_new=2))
    prev_out, prev_pre = len(resident.out), sched.stats["prefills"]
    while not resident.done:
        sched.step()
        assert len(resident.out) - prev_out == 1
        assert sched.stats["prefills"] - prev_pre <= 1
        prev_out, prev_pre = len(resident.out), sched.stats["prefills"]
    assert resident.error is None and len(resident.out) == 12


def test_slot_recycled_after_midstream_poison(lm):
    """A mid-stream decode failure frees its slot (cache slice zeroed for
    the next admission) and never leaks: the neighbour finishes, and a
    request submitted afterwards is served by the recycled slot."""
    sched = _sched(lm, slots=2, max_retries=1)
    inner = sched._default_fn
    state = {"calls": 0}

    def fn(p, t, c, q):
        state["calls"] += 1
        # decode call 3 fails, call 4 exhausts the retry, call 5 is the
        # slot-0 isolation probe reproducing it -> slot 0 is the poison
        if 3 <= state["calls"] <= 5:
            raise RuntimeError("mid-stream fault")
        return inner(p, t, c, q)

    sched.decode_fn = fn
    first = Request(rid=0, prompt=[1, 2], max_new=8)
    second = Request(rid=1, prompt=[3], max_new=3)
    sched.submit(first)
    sched.submit(second)
    _drain(sched)
    assert first.done and first.error and "fault" in first.error
    assert second.done and second.error is None and len(second.out) == 3
    assert sched.stats["failed"] == 1 and sched.stats["probes"] >= 1
    assert all(s is None for s in sched.slots)
    assert (sched.pos == 0).all()
    late = Request(rid=2, prompt=[5, 6], max_new=2)
    sched.submit(late)
    _drain(sched)
    assert late.done and late.error is None
    # the recycled slot serves the same bits as a fresh scheduler
    assert late.out == _solo_stream(lm, [5, 6], 2)


def test_deadline_evicts_in_continuous_mode(lm):
    sched = _sched(lm)
    req = Request(rid=0, prompt=[1, 2], max_new=20, deadline=3)
    sched.submit(req)
    _drain(sched)
    assert req.done and req.error == "deadline"
    assert sched.stats["deadline_expired"] == 1
    assert all(s is None for s in sched.slots)


def test_prompt_near_cap_terminates(lm):
    sched = _sched(lm)
    req = Request(rid=0, prompt=list(range(1, MAX_LEN - 1)), max_new=8)
    sched.submit(req)
    _drain(sched)
    assert req.done and req.error is None and 1 <= len(req.out) <= 8


# --------------------------------------------------- code-cache contract
def test_code_cache_dtype_and_memory_ratio(lm):
    """wl=8 codes are int8 and halve the bf16 cache bytes exactly; the
    per-block scale planes are accounted separately and stay small."""
    cfg, _, _ = lm
    sched = _sched(lm)
    assert sched.caches["k_codes"].dtype == jnp.int8
    assert sched.caches["k_scale"].dtype == jnp.float32
    rep = memory_report(cfg, SLOTS, MAX_LEN, wl=WL)
    assert rep["ratio_codes"] == 2.0
    assert rep["ratio_total"] > 1.5
    assert rep["scale_overhead"] < 0.25


def test_kv_codes_requires_attention_routing():
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode="bitexact", mul="bbm0", wl=WL, param=VBL,
                           apply_to="mlp"))          # attention not routed
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="attention lowering"):
        Scheduler(cfg, rt, params, 1, MAX_LEN, kv_codes=True)


def test_kv_codes_rejects_exact_budget_guard(lm):
    """The guard's sampled budget audit replays steps on the exact
    datapath, which cannot read an int-code cache — rejected up front."""
    cfg, rt, params = lm
    guard = GuardConfig(budget_abs=0.0, budget_every=1)
    with pytest.raises(ValueError, match="guard budget audit"):
        Scheduler(cfg, rt, params, 1, MAX_LEN, kv_codes=True, guard=guard)
