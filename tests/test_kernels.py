"""Pallas kernel sweeps vs. pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bbm_matmul, flash_attention, quant_matmul
from repro.kernels.ref import attention_ref, bbm_matmul_ref, quant_matmul_ref

RNG = np.random.default_rng(42)


# ------------------------------------------------------------- bbm_matmul
@pytest.mark.parametrize("wl,vbl,kind", [
    (8, 0, 0), (8, 5, 0), (8, 7, 1),
    (12, 0, 0), (12, 7, 0), (12, 11, 1), (12, 13, 0),
])
@pytest.mark.parametrize("shape", [(16, 32, 16), (48, 96, 80), (33, 65, 17)])
def test_bbm_matmul_matches_ref(wl, vbl, kind, shape):
    m, k, n = shape
    x = jnp.asarray(RNG.integers(0, 1 << wl, (m, k)), jnp.int32)
    w = jnp.asarray(RNG.integers(0, 1 << wl, (k, n)), jnp.int32)
    got = bbm_matmul(x, w, wl=wl, vbl=vbl, kind=kind, bm=16, bk=32, bn=16)
    ref = bbm_matmul_ref(x, w, wl=wl, vbl=vbl, kind=kind)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_bbm_matmul_shift_semantics():
    wl = 16
    x = jnp.asarray(RNG.integers(0, 1 << wl, (8, 64)), jnp.int32)
    w = jnp.asarray(RNG.integers(0, 1 << wl, (64, 8)), jnp.int32)
    got = bbm_matmul(x, w, wl=wl, vbl=13, shift=15, bm=8, bk=32, bn=8)
    ref = bbm_matmul_ref(x, w, wl=wl, vbl=13, shift=15)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_bbm_matmul_overflow_guard():
    x = jnp.zeros((4, 4096), jnp.int32)
    w = jnp.zeros((4096, 4), jnp.int32)
    with pytest.raises(ValueError, match="overflow"):
        bbm_matmul(x, w, wl=16, vbl=13)


def test_bbm_matmul_exactness_at_vbl0():
    """VBL=0 -> kernel computes the exact integer matmul."""
    wl = 10
    x = RNG.integers(0, 1 << wl, (24, 48)).astype(np.int32)
    w = RNG.integers(0, 1 << wl, (48, 24)).astype(np.int32)
    got = bbm_matmul(jnp.asarray(x), jnp.asarray(w), wl=wl, vbl=0,
                     bm=8, bk=16, bn=8)
    sx = np.where(x >= 1 << (wl - 1), x - (1 << wl), x).astype(np.int64)
    sw = np.where(w >= 1 << (wl - 1), w - (1 << wl), w).astype(np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), sx @ sw)


# ------------------------------------------------------------ quant_matmul
@pytest.mark.parametrize("shape", [(32, 128, 32), (64, 256, 48), (16, 64, 16)])
def test_quant_matmul_noiseless_exact(shape):
    """With sums inside f32's exact-int range the kernel == oracle bitwise."""
    m, k, n = shape
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
    s = 0.05   # codes ~ +-60 -> |sum| < 2^24
    got = quant_matmul(x, w, s, s, 0.0, 0.0, bm=16, bk=64, bn=16)
    ref = quant_matmul_ref(x, w, s, s, 0.0, 0.0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_quant_matmul_large_scale_close():
    x = jnp.asarray(RNG.standard_normal((64, 512)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((512, 64)), jnp.float32)
    got = quant_matmul(x, w, 1e-3, 1e-3, 0.0, 0.0, bm=32, bk=128, bn=32)
    ref = quant_matmul_ref(x, w, 1e-3, 1e-3, 0.0, 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-2)


def test_quant_matmul_noise_moments():
    """Injected noise must match the calibrated moments (paper §II.B)."""
    m, k, n = 128, 256, 128
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
    s, mu, sigma = 0.05, -3.5, 12.0
    base = quant_matmul(x, w, s, s, 0.0, 0.0, bm=32, bk=64, bn=32)
    noisy = quant_matmul(x, w, s, s, mu, sigma, seed=3, bm=32, bk=64, bn=32)
    eps = (np.asarray(noisy) - np.asarray(base)) / (s * s)
    assert eps.mean() == pytest.approx(mu * k, rel=0.05)
    assert eps.std() == pytest.approx(sigma * np.sqrt(k), rel=0.05)


def test_quant_matmul_noise_deterministic_and_seeded():
    x = jnp.asarray(RNG.standard_normal((32, 64)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((64, 32)), jnp.float32)
    a = quant_matmul(x, w, 0.05, 0.05, -1.0, 5.0, seed=1, bm=16, bk=32, bn=16)
    b = quant_matmul(x, w, 0.05, 0.05, -1.0, 5.0, seed=1, bm=16, bk=32, bn=16)
    c = quant_matmul(x, w, 0.05, 0.05, -1.0, 5.0, seed=2, bm=16, bk=32, bn=16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(2, 4, 128, 64), (1, 2, 160, 32),
                                   (1, 1, 96, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(dtype, shape, causal):
    b, h, s, d = shape
    q = jnp.asarray(RNG.standard_normal(shape), dtype)
    k = jnp.asarray(RNG.standard_normal(shape), dtype)
    v = jnp.asarray(RNG.standard_normal(shape), dtype)
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=causal)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_attention_cross_lengths():
    """Decode-like shape: few queries against a long KV."""
    q = jnp.asarray(RNG.standard_normal((1, 2, 32, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 256, 64)), jnp.float32)
    got = flash_attention(q, k, v, causal=False, bq=32, bk=64)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------- fir kernel
@pytest.mark.parametrize("wl,vbl,kind", [(10, 0, 0), (12, 9, 0), (12, 7, 1)])
@pytest.mark.parametrize("n,block", [(500, 128), (1024, 256)])
def test_fir_bbm_matches_per_tap_reference(wl, vbl, kind, n, block):
    from repro.core.bbm import bbm_type0, bbm_type1
    from repro.kernels.fir_kernel import fir_bbm
    taps = 31
    x = jnp.asarray(RNG.integers(0, 1 << wl, n), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, taps), jnp.int32)
    got = np.asarray(fir_bbm(x, h, wl=wl, vbl=vbl, kind=kind, block=block,
                             interpret=True), np.int64)
    fn = bbm_type0 if kind == 0 else bbm_type1
    xp = np.concatenate([np.zeros(taps - 1, np.int32), np.asarray(x)])
    ref = np.zeros(n, np.int64)
    for t in range(taps):
        ref += np.asarray(fn(jnp.asarray(xp[taps - 1 - t:taps - 1 - t + n]),
                             h[t], wl, vbl), np.int64)
    np.testing.assert_array_equal(got, ref)


def test_fir_bbm_overflow_guard():
    from repro.kernels.fir_kernel import fir_bbm
    x = jnp.zeros(64, jnp.int32)
    h = jnp.zeros(64, jnp.int32)
    with pytest.raises(ValueError, match="overflow"):
        fir_bbm(x, h, wl=16, vbl=13)
