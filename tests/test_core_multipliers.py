"""Unit + property tests for the core approximate-multiplier arithmetic."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (bam_mul, bbm_type0, bbm_type1, booth_mul_exact,
                        booth_digits, kulkarni_mul, to_signed, MulSpec, mul)
from repro.core.ref_sim import bam_ref, bbm_ref, kulkarni_ref

RNG = np.random.default_rng(1234)


def rand_ops(wl, n=256):
    return (RNG.integers(0, 1 << wl, n).astype(np.int32),
            RNG.integers(0, 1 << wl, n).astype(np.int32))


# ---------------------------------------------------------------- exact booth
@pytest.mark.parametrize("wl", [4, 6, 8, 10, 12, 16])
def test_booth_exact_equals_product(wl):
    a, b = rand_ops(wl, 512)
    got = np.asarray(booth_mul_exact(jnp.asarray(a), jnp.asarray(b), wl))
    sa = np.asarray(to_signed(jnp.asarray(a), wl))
    sb = np.asarray(to_signed(jnp.asarray(b), wl))
    np.testing.assert_array_equal(got, sa * sb)


def test_booth_exact_exhaustive_wl8():
    a = np.arange(256, dtype=np.int32)
    A, B = np.meshgrid(a, a)
    got = np.asarray(booth_mul_exact(jnp.asarray(A), jnp.asarray(B), 8))
    s = np.where(a >= 128, a - 256, a)
    SA, SB = np.meshgrid(s, s)
    np.testing.assert_array_equal(got, SA * SB)


def test_booth_digits_reconstruct():
    wl = 12
    b = jnp.arange(1 << wl, dtype=jnp.int32)
    d, _ = booth_digits(b, wl)
    w = jnp.int32(4) ** jnp.arange(wl // 2)
    recon = jnp.sum(d * w, axis=-1)
    np.testing.assert_array_equal(np.asarray(recon),
                                  np.asarray(to_signed(b, wl)))


# ------------------------------------------------------- bbm vs dot-level ref
@pytest.mark.parametrize("wl", [4, 8, 12, 16])
@pytest.mark.parametrize("kind", [0, 1])
def test_bbm_matches_dot_level_ref(wl, kind):
    fn = bbm_type0 if kind == 0 else bbm_type1
    limit = 2 * wl - 6 if wl >= 14 else 2 * wl
    for vbl in sorted({0, 1, 3, wl - 1, wl, min(wl + 3, limit), limit}):
        a, b = rand_ops(wl)
        got = np.asarray(fn(jnp.asarray(a), jnp.asarray(b), wl, vbl))
        ref = np.array([bbm_ref(int(x), int(y), wl, vbl, kind)
                        for x, y in zip(a, b)])
        np.testing.assert_array_equal(got, ref, err_msg=f"vbl={vbl}")


def test_bbm_vbl0_is_exact():
    for kind, fn in ((0, bbm_type0), (1, bbm_type1)):
        a, b = rand_ops(12, 1024)
        got = np.asarray(fn(jnp.asarray(a), jnp.asarray(b), 12, 0))
        sa = np.asarray(to_signed(jnp.asarray(a), 12))
        sb = np.asarray(to_signed(jnp.asarray(b), 12))
        np.testing.assert_array_equal(got, sa * sb)


def test_bbm_type0_error_nonpositive():
    # Type0 truncation floors each row -> error <= 0 always.
    a, b = rand_ops(12, 4096)
    for vbl in (3, 7, 11):
        approx = np.asarray(bbm_type0(jnp.asarray(a), jnp.asarray(b), 12, vbl))
        sa = np.asarray(to_signed(jnp.asarray(a), 12))
        sb = np.asarray(to_signed(jnp.asarray(b), 12))
        assert (approx - sa * sb).max() <= 0


def test_bbm_vbl_guard():
    with pytest.raises(ValueError):
        bbm_type0(jnp.int32(1), jnp.int32(1), 16, 31)


# ------------------------------------------------------------- bam / kulkarni
@pytest.mark.parametrize("wl", [4, 8, 12])
def test_bam_matches_ref(wl):
    for vbl in (0, 2, wl - 1, wl + 2):
        for hbl in (0, 1):
            a, b = rand_ops(wl)
            got = np.asarray(bam_mul(jnp.asarray(a), jnp.asarray(b), wl, vbl, hbl))
            ref = np.array([bam_ref(int(x), int(y), wl, vbl, hbl)
                            for x, y in zip(a, b)])
            np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("wl", [4, 8, 12])
def test_kulkarni_matches_ref(wl):
    for k in (0, 3, 5, wl, 2 * wl - 1):
        a, b = rand_ops(wl)
        got = np.asarray(kulkarni_mul(jnp.asarray(a), jnp.asarray(b), wl, k))
        ref = np.array([kulkarni_ref(int(x), int(y), wl, k)
                        for x, y in zip(a, b)])
        np.testing.assert_array_equal(got, ref)


def test_kulkarni_known_block():
    # the single inaccurate case of the 2x2 block: 3*3 -> 7
    assert kulkarni_ref(3, 3, 2, k=4) == 7
    assert kulkarni_ref(3, 3, 2, k=0) == 9
    got = np.asarray(kulkarni_mul(jnp.int32(3), jnp.int32(3), 2, 4))
    assert int(got) == 7


# ---------------------------------------------------------- hypothesis props
@given(a=st.integers(0, (1 << 12) - 1), b=st.integers(0, (1 << 12) - 1),
       vbl=st.integers(0, 23), kind=st.sampled_from([0, 1]))
@settings(max_examples=300, deadline=None)
def test_prop_bbm_matches_ref(a, b, vbl, kind):
    fn = bbm_type0 if kind == 0 else bbm_type1
    got = int(np.asarray(fn(jnp.int32(a), jnp.int32(b), 12, vbl)))
    assert got == bbm_ref(a, b, 12, vbl, kind)


@given(a=st.integers(0, (1 << 12) - 1), b=st.integers(0, (1 << 12) - 1),
       vbl=st.integers(0, 23))
@settings(max_examples=200, deadline=None)
def test_prop_bbm_error_bound(a, b, vbl):
    """|error| is bounded by the sum of maskable row weights."""
    got = int(np.asarray(bbm_type0(jnp.int32(a), jnp.int32(b), 12, vbl)))
    exact = ((a - 4096 if a >= 2048 else a) * (b - 4096 if b >= 2048 else b))
    bound = sum((1 << max(0, vbl - 2 * i)) - 1 << (2 * i) for i in range(6)
                if vbl - 2 * i > 0)
    assert exact - bound <= got <= exact


@given(a=st.integers(0, (1 << 10) - 1), b=st.integers(0, (1 << 10) - 1),
       vbl=st.integers(0, 19), hbl=st.integers(0, 9))
@settings(max_examples=200, deadline=None)
def test_prop_bam_monotone_truncation(a, b, vbl, hbl):
    """BAM only ever removes dots: 0 <= approx <= exact product."""
    got = int(np.asarray(bam_mul(jnp.int32(a), jnp.int32(b), 10, vbl, hbl)))
    assert 0 <= got <= a * b
    assert got == bam_ref(a, b, 10, vbl, hbl)


# --------------------------------------------------------------- registry api
def test_registry_signed_wrapping():
    spec = MulSpec("bam", 8, 3)
    f = mul(spec)
    a = jnp.asarray([-5 & 0xFF, 7], dtype=jnp.int32)
    b = jnp.asarray([9, -3 & 0xFF], dtype=jnp.int32)
    out = np.asarray(f(a, b))
    ref0 = -bam_ref(5, 9, 8, 3)
    ref1 = -bam_ref(7, 3, 8, 3)
    np.testing.assert_array_equal(out, [ref0, ref1])


def test_registry_exactness_flags():
    assert MulSpec("booth", 16, 0).is_exact
    assert MulSpec("bbm0", 12, 0).is_exact
    assert not MulSpec("bbm0", 12, 5).is_exact


# regression for the and/or-precedence bug in MulSpec.is_exact: the flag is
# checked *empirically* against an exhaustive wl=4 sweep for every
# registered multiplier and a spread of knob settings
@pytest.mark.parametrize("name,param,hbl", [
    ("booth", 0, 0), ("booth", 4, 0),      # param is ignored: always exact
    ("bbm0", 0, 0), ("bbm0", 3, 0),
    ("bbm1", 0, 0), ("bbm1", 3, 0),
    ("bam", 0, 0), ("bam", 3, 0), ("bam", 0, 2),   # hbl alone inexact
    ("kulkarni", 0, 0), ("kulkarni", 4, 0),
    ("etm", 0, 0), ("etm", 2, 0),
])
def test_is_exact_matches_behavior(name, param, hbl):
    wl = 4
    spec = MulSpec(name, wl, param, hbl)
    a = np.arange(1 << wl, dtype=np.int32)
    A, B = [jnp.asarray(v) for v in np.meshgrid(a, a)]
    got = np.asarray(mul(spec)(A, B))
    s = np.where(a >= 1 << (wl - 1), a - (1 << wl), a)
    SA, SB = np.meshgrid(s, s)
    empirically_exact = bool(np.array_equal(got, SA * SB))
    assert spec.is_exact == empirically_exact, (
        f"{spec} reports is_exact={spec.is_exact} but the exhaustive wl=4 "
        f"sweep says {empirically_exact}")


# ------------------------------------------------------------------ ETM
def test_etm_exact_for_small_operands():
    from repro.core.etm import etm_mul
    wl, split = 10, 5
    a = RNG.integers(0, 1 << split, 300).astype(np.int32)
    b = RNG.integers(0, 1 << split, 300).astype(np.int32)
    got = np.asarray(etm_mul(jnp.asarray(a), jnp.asarray(b), wl, split))
    np.testing.assert_array_equal(got, a * b)


def test_etm_relative_error_bounded():
    """ETM's fill-with-ones rule bounds the low-part error by 2^(2*split)."""
    from repro.core.etm import etm_mul
    wl, split = 12, 6
    a, b = rand_ops(wl, 2048)
    got = np.asarray(etm_mul(jnp.asarray(a), jnp.asarray(b), wl, split),
                     np.int64)
    exact = a.astype(np.int64) * b.astype(np.int64)
    err = got - exact
    assert np.abs(err).max() < (1 << (2 * split))


def test_etm_split0_exact():
    from repro.core.etm import etm_mul
    a, b = rand_ops(12, 256)
    got = np.asarray(etm_mul(jnp.asarray(a), jnp.asarray(b), 12, 0))
    np.testing.assert_array_equal(got, a * b)
