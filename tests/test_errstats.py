"""Error characterization vs. the paper's Table I (exact reproduction)."""
import numpy as np
import pytest

from repro.core import MulSpec, characterize, error_histogram
from repro.core.hwmodel import (PAPER_AREA_REDUCTION, PAPER_POWER_REDUCTION,
                                area, power, tmin)
from repro.core.multipliers import MulSpec as MS

# paper Table I: vbl -> (mean, mse, prob, min)
TABLE1 = {
    3: (-3.50, 2.22e1, 0.6875, -1.10e1),
    6: (-6.15e1, 5.05e3, 0.9375, -1.71e2),
    9: (-7.89e2, 7.52e5, 0.9893, -2.22e3),
    12: (-8.53e3, 8.33e7, 0.9983, -2.32e4),
}


@pytest.mark.parametrize("vbl", sorted(TABLE1))
def test_table1_exhaustive_wl12(vbl):
    """Exhaustive 2^24-pair characterization must match the paper's digits."""
    pm, pmse, pprob, pmin = TABLE1[vbl]
    st = characterize(MulSpec("bbm0", 12, vbl))
    assert st.n == 1 << 24
    assert st.mean == pytest.approx(pm, rel=7e-3)
    assert st.mse == pytest.approx(pmse, rel=7e-3)
    assert st.prob == pytest.approx(pprob, abs=1e-4)
    assert st.min == pytest.approx(pmin, rel=5e-3)
    assert st.max <= 0  # Type0 truncation never overshoots


def test_error_monotone_in_vbl():
    prev = None
    for vbl in (0, 2, 4, 6, 8):
        st = characterize(MulSpec("bbm0", 12, vbl), exhaustive=False,
                          sample=1 << 16, seed=7)
        if prev is not None:
            assert st.mse >= prev.mse
        prev = st
    assert characterize(MulSpec("bbm0", 12, 0)).mse == 0.0


def test_sampled_close_to_exhaustive():
    ex = characterize(MulSpec("bbm0", 10, 7))
    sa = characterize(MulSpec("bbm0", 10, 7), exhaustive=False,
                      sample=1 << 18, seed=3)
    assert sa.mse == pytest.approx(ex.mse, rel=0.05)
    assert sa.mean == pytest.approx(ex.mean, rel=0.05)


def test_fig2_histogram_mass():
    centers, pct = error_histogram(MulSpec("bbm0", 10, 9), bins=41)
    assert pct.sum() == pytest.approx(100.0)
    # truncation error is <= 0: no mass beyond the zero bin
    assert pct[centers > 0.005].sum() == pytest.approx(0.0, abs=1e-12)
    # the adaptive range resolves the distribution over many bins
    assert (pct > 0.1).sum() >= 10


def test_type1_worse_than_type0():
    """Paper: Type1 trades accuracy for power (higher MSE at equal VBL)."""
    st0 = characterize(MulSpec("bbm0", 12, 9), exhaustive=False,
                       sample=1 << 18, seed=5)
    st1 = characterize(MulSpec("bbm1", 12, 9), exhaustive=False,
                       sample=1 << 18, seed=5)
    assert st1.mse > st0.mse
    assert power(MS("bbm1", 12, 9)) < power(MS("bbm0", 12, 9))


# ------------------------------------------------------------- hwmodel checks
def test_hwmodel_calibration_close_to_paper():
    for wl in (4, 8, 12, 16):
        pr = 100 * (1 - power(MS("bbm0", wl, wl - 1)) / power(MS("bbm0", wl, 0)))
        ar = 100 * (1 - area(MS("bbm0", wl, wl - 1)) / area(MS("bbm0", wl, 0)))
        assert pr == pytest.approx(PAPER_POWER_REDUCTION[wl], abs=8.0)
        assert ar == pytest.approx(PAPER_AREA_REDUCTION[wl], abs=6.0)


def test_hwmodel_tmin_matches_fig3():
    assert tmin(MS("booth", 16, 0)) == pytest.approx(1.21, abs=0.01)
    assert tmin(MS("bbm0", 16, 15)) == pytest.approx(1.13, abs=0.01)


def test_hwmodel_monotone():
    powers = [power(MS("bbm0", 12, v)) for v in range(0, 12, 2)]
    areas = [area(MS("bbm0", 12, v)) for v in range(0, 12, 2)]
    assert all(x >= y for x, y in zip(powers, powers[1:]))
    assert all(x >= y for x, y in zip(areas, areas[1:]))
