"""Shared test configuration: import paths + optional-dependency guards.

Two jobs, both aimed at "collection never hard-fails":

1. Make ``repro`` importable from a bare checkout (src layout) even when
   pytest's ``pythonpath`` ini option is unavailable or the package is not
   installed.

2. Keep test modules that use optional dependencies collectable when those
   dependencies are missing.  ``hypothesis`` is the interesting case: two
   modules import it at the top for a handful of property tests while the
   bulk of their tests need nothing but numpy/jax.  When hypothesis is
   absent we install a tiny stub whose ``@given`` marks each property test
   as skipped (``pytest.importorskip`` semantics, applied per-test instead
   of per-module, so the ~40 non-property tests in those files still run).
   Genuinely load-bearing optional deps (scipy) skip the whole module.
"""
from __future__ import annotations

import importlib.util
import sys
import types
from pathlib import Path

import pytest

_SRC = Path(__file__).resolve().parents[1] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

# modules whose *collection* requires the optional dep -> skip whole file
# (repro.dsp imports scipy.signal.remez at module top)
_OPTIONAL_MODULE_DEPS = {
    "scipy": ["test_dsp.py", "test_filterbank.py"],
}

collect_ignore = []
for _dep, _files in _OPTIONAL_MODULE_DEPS.items():
    if importlib.util.find_spec(_dep) is None:
        collect_ignore.extend(_files)


def _install_hypothesis_stub() -> None:
    """A skip-everything stand-in for the hypothesis API surface we use."""
    hyp = types.ModuleType("hypothesis")
    hyp.__stub__ = True

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[dev])")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    st = types.ModuleType("hypothesis.strategies")
    st.__stub__ = True

    def _strategy(*_args, **_kwargs):
        return None

    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "composite"):
        setattr(st, _name, _strategy)

    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


if importlib.util.find_spec("hypothesis") is None:
    _install_hypothesis_stub()


@pytest.fixture(autouse=True, scope="module")
def _bound_live_xla_executables():
    """Drop jit/pjit caches after every test module.

    The suite compiles thousands of XLA:CPU programs; keeping every
    executable's JIT code pages alive for the whole run eventually drives
    the process into native-resource exhaustion and a segfault inside
    ``backend_compile`` (first seen compiling the Pallas FIR kernels late
    in the run).  Tests never rely on compilation caches surviving across
    modules — the bitwise contracts are all path-vs-path within a test —
    so the teardown is free apart from per-module recompiles.
    """
    yield
    import jax

    jax.clear_caches()
