"""Validation of the §II.B white-noise error model against bit-exact runs.

The scalable path (quantize -> exact matmul -> calibrated noise) must match
the *moments* of the true approximate-multiplier datapath; this is the
paper's own analysis method turned into a testable claim.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MulSpec, characterize, make_noise_model
from repro.core.booth import to_signed
from repro.core.multipliers import mul
from repro.kernels.ref import bbm_matmul_ref


@pytest.mark.parametrize("vbl", [5, 7, 9])
def test_dot_error_moments_match_bitexact(vbl):
    """Accumulated error of a K-dot-product ~ Normal(K*mu, K*sigma^2)."""
    wl, k_len, n_trials = 10, 64, 3000
    spec = MulSpec("bbm0", wl, vbl)
    nm = make_noise_model(spec, sample=1 << 18)
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << wl, (n_trials, k_len)).astype(np.int32)
    b = rng.integers(0, 1 << wl, (n_trials, k_len)).astype(np.int32)
    approx = np.asarray(mul(spec)(jnp.asarray(a), jnp.asarray(b)),
                        np.int64).sum(axis=1)
    sa = np.asarray(to_signed(jnp.asarray(a), wl), np.int64)
    sb = np.asarray(to_signed(jnp.asarray(b), wl), np.int64)
    exact = (sa * sb).sum(axis=1)
    err = (approx - exact).astype(np.float64)
    mu_pred, sd_pred = nm.dot_moments(k_len)
    assert err.mean() == pytest.approx(mu_pred, rel=0.1)
    assert err.std() == pytest.approx(sd_pred, rel=0.15)


def test_error_variance_scales_linearly_in_k():
    wl, vbl = 10, 7
    spec = MulSpec("bbm0", wl, vbl)
    rng = np.random.default_rng(1)
    stds = []
    for k_len in (16, 64):
        a = rng.integers(0, 1 << wl, (2000, k_len)).astype(np.int32)
        b = rng.integers(0, 1 << wl, (2000, k_len)).astype(np.int32)
        approx = np.asarray(mul(spec)(jnp.asarray(a), jnp.asarray(b)),
                            np.int64).sum(axis=1)
        sa = np.asarray(to_signed(jnp.asarray(a), wl), np.int64)
        sb = np.asarray(to_signed(jnp.asarray(b), wl), np.int64)
        err = (approx - (sa * sb).sum(axis=1)).astype(np.float64)
        stds.append(err.std())
    assert stds[1] / stds[0] == pytest.approx(2.0, rel=0.2)  # sqrt(64/16)


def test_noise_model_cache():
    s1 = make_noise_model(MulSpec("bbm0", 12, 9), sample=1 << 16)
    s2 = make_noise_model(MulSpec("bbm0", 12, 9), sample=1 << 16)
    assert s1 is s2


def test_moments_match_errstats():
    spec = MulSpec("bbm1", 10, 6)
    st = characterize(spec)
    nm = make_noise_model(spec, sample=1 << 18)
    assert nm.mean == pytest.approx(st.mean, rel=0.05)
    assert nm.var == pytest.approx(st.var, rel=0.1)
