"""Batched multi-channel FIR filterbank subsystem tests.

Property sweeps promised by the subsystem: the Pallas filterbank kernel
(interpret mode) is bit-for-bit equal to the host fixed-point datapath for
>= 4 channels x 2 tap banks across wl in {8, 12, 16}, both BBM kinds and a
vbl spread; ``bbm_matmul`` equals the closed-form ``bbm_mul`` accumulation;
and the int32 overflow envelope rejects unsafe taps x wl combinations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bbm import bbm_mul
from repro.core.multipliers import MulSpec
from repro.dsp import design_lowpass, fir_apply, fir_apply_fixed
from repro.kernels import bbm_matmul, fir_bbm, fir_bbm_bank, min_safe_shift
from repro.kernels.ref import fir_bank_ref

RNG = np.random.default_rng(7)

# (wl, vbl) sweep points; kind 0/1 covers bbm0/bbm1
SWEEP = [(8, 0), (8, 5), (12, 7), (12, 11), (16, 13), (16, 15)]


def _bank_case(channels, n, taps, wl):
    x = jnp.asarray(RNG.integers(0, 1 << wl, (channels, n)), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, (channels, taps)), jnp.int32)
    return x, h


# ------------------------------------------------------------- kernel level
@pytest.mark.parametrize("wl,vbl", SWEEP)
@pytest.mark.parametrize("kind", [0, 1])
def test_fir_bank_kernel_matches_closed_form(wl, vbl, kind):
    """(C, N) kernel vs the pure-jnp closed-form oracle, bit for bit."""
    channels, n, taps = 5, 700, 31
    shift = min_safe_shift(taps, wl)
    x, h = _bank_case(channels, n, taps, wl)
    got = fir_bbm_bank(x, h, wl=wl, vbl=vbl, kind=kind, shift=shift,
                       bc=2, bt=128, interpret=True, form="rows")
    ref = fir_bank_ref(x, h, wl=wl, vbl=vbl, kind=kind, shift=shift)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_fir_bank_halo_streams_across_many_blocks():
    """Small time blocks force many halo hand-offs; result is unchanged."""
    wl, vbl, kind, taps = 12, 9, 1, 31
    x, h = _bank_case(3, 1024, taps, wl)
    ref = np.asarray(fir_bbm_bank(x, h, wl=wl, vbl=vbl, kind=kind,
                                  bc=3, bt=1024, interpret=True,
                                  form="rows"))
    for bt in (64, 128, 256):
        got = np.asarray(fir_bbm_bank(x, h, wl=wl, vbl=vbl, kind=kind,
                                      bc=2, bt=bt, interpret=True,
                                      form="rows"))
        np.testing.assert_array_equal(got, ref, err_msg=f"bt={bt}")


def test_fir_bank_shared_taps_broadcast():
    wl, taps = 10, 31
    x, _ = _bank_case(4, 300, taps, wl)
    h1 = jnp.asarray(RNG.integers(0, 1 << wl, taps), jnp.int32)
    got = fir_bbm_bank(x, h1, wl=wl, vbl=5, interpret=True,
                       form="rows")
    ref = fir_bank_ref(x, jnp.broadcast_to(h1, (4, taps)), wl=wl, vbl=5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_fir_bbm_1d_wrapper_matches_bank():
    wl, vbl, kind = 12, 7, 0
    x = jnp.asarray(RNG.integers(0, 1 << wl, 500), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, 31), jnp.int32)
    got = fir_bbm(x, h, wl=wl, vbl=vbl, kind=kind, block=128,
                  interpret=True, form="rows")
    ref = fir_bank_ref(x[None], h[None], wl=wl, vbl=vbl, kind=kind)[0]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# --------------------------------------------------- kernel vs host datapath
@pytest.mark.parametrize("wl,vbl", SWEEP)
@pytest.mark.parametrize("name", ["bbm0", "bbm1"])
def test_filterbank_backends_bit_exact(wl, vbl, name):
    """fir_apply host vs pallas-interpret: equal floats, 4 ch x 2 banks."""
    spec = MulSpec(name, wl, vbl)
    x = RNG.standard_normal((4, 600))
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    h = banks[[0, 1, 0, 1]]
    a = fir_apply(x, h, spec, backend="host")
    b = fir_apply(x, h, spec, backend="pallas-interpret", block=128, bc=2)
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("wl", [8, 12])
def test_fir_bbm_matches_fir_apply_fixed(wl):
    """The interpreted kernel reproduces the original host path exactly.

    ``fir_apply_fixed`` is the seed's shift=0 single-channel entry point;
    wl <= 12 keeps 31 taps inside the int32 envelope without a shift.
    """
    for name, vbl in (("bbm0", 5), ("bbm1", 7), ("booth", 0)):
        spec = MulSpec(name, wl, vbl)
        x = RNG.standard_normal(777)
        h = design_lowpass()
        host = fir_apply_fixed(x, h, spec)
        kern = fir_apply(x, h, spec, backend="pallas-interpret", shift=0,
                         block=256)
        np.testing.assert_array_equal(host, kern)


# ------------------------------------------------- bbm_matmul vs closed form
@pytest.mark.parametrize("wl,vbl", SWEEP)
@pytest.mark.parametrize("kind", [0, 1])
def test_bbm_matmul_matches_bbm_mul(wl, vbl, kind):
    """Kernel matmul == per-element closed-form bbm_mul, then sum over K."""
    m, k, n = 8, 32, 8
    shift = min_safe_shift(k, wl)
    x = jnp.asarray(RNG.integers(0, 1 << wl, (m, k)), jnp.int32)
    w = jnp.asarray(RNG.integers(0, 1 << wl, (k, n)), jnp.int32)
    got = np.asarray(bbm_matmul(x, w, wl=wl, vbl=vbl, kind=kind, shift=shift,
                                bm=8, bk=16, bn=8, interpret=True,
                                form="rows"), np.int64)
    prod = np.asarray(bbm_mul(x[:, :, None], w[None, :, :], wl, vbl,
                              kind=kind), np.int64)
    ref = np.sum(prod >> shift, axis=1)
    np.testing.assert_array_equal(got, ref)


# --------------------------------------------------------- overflow envelope
@pytest.mark.parametrize("taps,wl,shift,ok", [
    (31, 12, 0, True),       # paper workload, no rescale needed
    (31, 16, 0, False),      # paper workload at wl=16 needs shift >= 5
    (31, 16, 5, True),
    (64, 16, 6, False),      # longer bank: 64 * 2^(31-6) == 2^31 exactly
    (64, 16, 7, True),
    (4096, 16, 0, False),
])
def test_overflow_envelope_guard(taps, wl, shift, ok):
    x = jnp.zeros((2, 64), jnp.int32)
    h = jnp.zeros((2, taps), jnp.int32)
    if ok:
        fir_bbm_bank(x, h, wl=wl, vbl=0, shift=shift, bt=64, interpret=True)
    else:
        with pytest.raises(ValueError, match="overflow"):
            fir_bbm_bank(x, h, wl=wl, vbl=0, shift=shift, bt=64,
                         interpret=True)
        assert min_safe_shift(taps, wl) > shift


def test_min_safe_shift_is_minimal():
    for taps, wl in ((31, 8), (31, 12), (31, 16), (64, 16), (1024, 16)):
        s = min_safe_shift(taps, wl)
        assert taps * (2 ** max(2 * wl - 1 - s, 0)) < 2 ** 31
        if s:
            assert taps * (2 ** max(2 * wl - 1 - (s - 1), 0)) >= 2 ** 31


# ------------------------------------------------------------ sharded + serve
def test_sharded_filterbank_single_device_mesh():
    from repro.parallel import sharded_filterbank
    wl, vbl, kind = 12, 9, 0
    mesh = jax.make_mesh((1,), ("data",))
    x, h = _bank_case(4, 256, 31, wl)
    got = sharded_filterbank(x, h, mesh, wl=wl, vbl=vbl, kind=kind)
    ref = fir_bank_ref(x, h, wl=wl, vbl=vbl, kind=kind)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # the interpreted kernel path agrees with the closed-form path
    got_k = sharded_filterbank(x, h, mesh, wl=wl, vbl=vbl, kind=kind,
                               use_kernel=True, bt=128)
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(ref))


def test_filterbank_engine_batches_requests():
    from repro.serve import FilterbankEngine
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    spec = MulSpec("bbm0", 16, 13)
    eng = FilterbankEngine(banks, spec, backend="host", max_channels=3)
    sigs = [RNG.standard_normal(n) for n in (400, 250, 400, 320)]
    rids = [eng.submit(s, bank=i % 2) for i, s in enumerate(sigs)]
    out = eng.flush()
    assert sorted(out) == sorted(rids)
    assert not eng._pending
    # serving determinism: the quantization scale is per channel, so the
    # same signal served alone produces bit-identical output to the one it
    # got riding in a zero-padded batch of 3
    solo = FilterbankEngine(banks, spec, backend="host")
    rid = solo.submit(sigs[1], bank=1)
    lone = solo.flush()[rid]
    np.testing.assert_array_equal(out[rids[1]], lone)
    # engine output == direct batched fir_apply on the padded batch
    x = np.zeros((3, 400))
    for c, s in enumerate(sigs[:3]):
        x[c, : len(s)] = s
    direct = fir_apply(x, banks[[0, 1, 0]], spec, backend="host")
    np.testing.assert_array_equal(out[rids[0]], direct[0, :400])
    np.testing.assert_array_equal(out[rids[1]], direct[1, :250])


def test_filterbank_engine_rejects_unknown_bank():
    from repro.serve import FilterbankEngine
    eng = FilterbankEngine(design_lowpass(), MulSpec("bbm0", 16, 13))
    with pytest.raises(ValueError, match="bank"):
        eng.submit(np.zeros(16), bank=2)
