"""Degradation paths in the serving engines: isolate, retry, never wedge.

Covers the ``FilterbankEngine`` quarantine ladder (retry -> bisection ->
eject; the regression for the dispatch-before-dequeue livelock), the
``Scheduler``'s per-slot failure isolation / deadlines / guard-tripped
exact re-serve, the scheduler edge cases (empty prompt, prompt past
``max_len``, slot recycling after a mid-stream failure, FIFO admission),
and the launcher-side early argument validation.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest
from numpy.testing import assert_array_equal

import jax
from repro.configs import get_arch, reduced
from repro.configs.base import AmmConfig
from repro.core.guards import GuardConfig
from repro.core.multipliers import MulSpec
from repro.dsp.fir import design_lowpass, fir_apply
from repro.models import ModelRuntime, lm_init
from repro.serve.engine import FilterbankEngine, Request, Scheduler

RNG = np.random.default_rng(23)
SPEC = MulSpec("bbm0", 16, 13)


# ------------------------------------------------------ FilterbankEngine
def _poisoned(engine, poison_sig):
    """Wrap the engine's dispatch to raise on batches holding one signal."""
    inner = engine._apply

    def flaky(x, h, spec, **kw):
        for row in np.asarray(x):
            if len(poison_sig) <= len(row) and np.array_equal(
                    row[: len(poison_sig)], poison_sig):
                raise RuntimeError("injected poison")
        return inner(x, h, spec, **kw)

    engine._apply = flaky


def test_poison_request_is_quarantined_not_livelocked():
    """Regression for the dispatch-before-dequeue wedge: one poison
    request used to re-raise out of every flush forever.  Now it is
    bisected down, quarantined into ``failed``, and every healthy
    neighbour in the same batch is served the same flush."""
    eng = FilterbankEngine(design_lowpass(), SPEC, backend="host",
                           max_channels=8, max_retries=1)
    sigs = [RNG.standard_normal(96) for _ in range(6)]
    _poisoned(eng, sigs[3])
    rids = [eng.submit(s) for s in sigs]
    out = eng.flush()
    assert set(out) == set(rids) - {rids[3]}
    assert rids[3] in eng.failed and "poison" in eng.failed[rids[3]]
    assert not eng._pending
    assert eng.flush() == {}             # drained: no re-raise, no wedge
    assert eng.stats["quarantined"] == 1 and eng.stats["bisections"] >= 1
    # healthy outputs are the normal datapath's, unchanged by the drama
    clean = FilterbankEngine(design_lowpass(), SPEC, backend="host")
    r0 = clean.submit(sigs[0])
    assert_array_equal(out[rids[0]], clean.flush()[r0])


def test_transient_failure_saved_by_retry():
    eng = FilterbankEngine(design_lowpass(), SPEC, backend="host",
                           max_retries=2)
    inner = eng._apply
    calls = {"n": 0}

    def transient(x, h, spec, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient blip")
        return inner(x, h, spec, **kw)

    eng._apply = transient
    rid = eng.submit(RNG.standard_normal(64))
    out = eng.flush()
    assert rid in out and not eng.failed
    assert eng.stats["retries"] == 1


def test_retries_exhausted_without_bisection_quarantines_singleton():
    eng = FilterbankEngine(design_lowpass(), SPEC, backend="host",
                           max_retries=1)

    def always(x, h, spec, **kw):
        raise RuntimeError("hard fault")

    eng._apply = always
    rid = eng.submit(RNG.standard_normal(32))
    assert eng.flush() == {}
    assert rid in eng.failed and eng.stats["retries"] == 1


def test_guard_trip_reserves_on_exact_datapath():
    """A zero error budget trips on any approximate output; the request
    must come back served by the exact Booth datapath, bit for bit."""
    guard = GuardConfig(budget_abs=0.0, budget_every=1)
    eng = FilterbankEngine(design_lowpass(), SPEC, backend="host",
                           guard=guard)
    sig = RNG.standard_normal(128)
    rid = eng.submit(sig)
    out = eng.flush()
    exact = fir_apply(sig, design_lowpass(), MulSpec("booth", 16, 0),
                      backend="host")
    assert_array_equal(out[rid], exact)
    assert eng.stats["guard_trips"] == 1
    assert eng.stats["exact_reserves"] == 1


def test_guard_quiet_when_within_budget():
    guard = GuardConfig(budget_abs=1.0, budget_every=1)
    eng = FilterbankEngine(design_lowpass(), SPEC, backend="host",
                           guard=guard)
    rid = eng.submit(RNG.standard_normal(64))
    out = eng.flush()
    assert rid in out and eng.stats["guard_trips"] == 0


# ------------------------------------------------------------- Scheduler
@pytest.fixture(scope="module")
def lm():
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode="bitexact", mul="bbm0", wl=16, param=13,
                           apply_to="mlp"))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    return cfg, rt, params


def _drain(sched, cap=200):
    steps = 0
    while sched.step():
        steps += 1
        assert steps < cap, "scheduler failed to terminate"
    return steps


def _poison_wrapper(sched, poison_tok):
    """decode_fn raising whenever a marker token is in the batch."""
    inner = sched._default_fn

    def fn(p, t, c, q):
        if (np.asarray(t) == poison_tok).any():
            raise RuntimeError("poison token")
        return inner(p, t, c, q)

    return fn


def test_poison_request_fails_alone(lm):
    """A deterministically-raising request must fail by itself: its slot
    neighbour decodes to completion in the same run."""
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, 2, 32, max_retries=1)
    sched.decode_fn = _poison_wrapper(sched, 499)   # in the reduced vocab
    good = Request(rid=0, prompt=[1, 2], max_new=3)
    bad = Request(rid=1, prompt=[499, 2], max_new=3)
    sched.submit(good)
    sched.submit(bad)
    _drain(sched)
    assert good.done and good.error is None and len(good.out) == 3
    assert bad.done and bad.error and "poison" in bad.error
    assert bad.out == []
    assert sched.stats["failed"] == 1 and sched.stats["probes"] >= 1
    assert sched.stats["retries"] == 1


def test_slot_recycled_after_midstream_failure(lm):
    """The poison hits mid-stream (after the prompt); the freed slot must
    admit and finish the queued request."""
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, 1, 32, max_retries=1)
    inner = sched._default_fn
    state = {"calls": 0}

    def fn(p, t, c, q):
        state["calls"] += 1
        # the third step fails hard enough to exhaust the retry (call 4)
        # and reproduce under the isolation probe (call 5)
        if 3 <= state["calls"] <= 5:
            raise RuntimeError("mid-stream fault")
        return inner(p, t, c, q)

    sched.decode_fn = fn
    first = Request(rid=0, prompt=[1, 2], max_new=8)
    second = Request(rid=1, prompt=[3], max_new=2)
    sched.submit(first)
    sched.submit(second)
    _drain(sched)
    assert first.done and first.error is not None
    assert second.done and second.error is None and len(second.out) == 2


def test_systemic_failure_reraises(lm):
    """A failure no single-slot probe reproduces is systemic: surface it
    instead of silently failing every request."""
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, 2, 32, max_retries=0)

    def always(p, t, c, q):
        raise RuntimeError("the accelerator is on fire")

    sched.decode_fn = always
    sched.submit(Request(rid=0, prompt=[1], max_new=1))
    with pytest.raises(RuntimeError, match="on fire"):
        sched.step()


def test_deadline_expires_request(lm):
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, 1, 32)
    req = Request(rid=0, prompt=[1, 2, 3, 4], max_new=20, deadline=6)
    sched.submit(req)
    _drain(sched)
    assert req.done and req.error == "deadline"
    assert sched.stats["deadline_expired"] == 1


def test_guard_trip_reserves_request_exactly(lm):
    """Zero budget + approximate datapath: every audited step trips, and
    the request is replayed on the exact datapath (mode="off")."""
    cfg, rt, params = lm
    guard = GuardConfig(budget_abs=0.0, budget_every=1)
    sched = Scheduler(cfg, rt, params, 1, 32, guard=guard)
    req = Request(rid=0, prompt=[1, 2, 3], max_new=3)
    sched.submit(req)
    _drain(sched)
    assert req.done and req.exact and len(req.out) == 3
    assert sched.stats["guard_trips"] >= 1
    assert sched.stats["exact_reserves"] == 1
    # the re-served output is what the exact scheduler produces
    cfg_off = dataclasses.replace(
        cfg, amm=dataclasses.replace(cfg.amm, mode="off"))
    rt_off = ModelRuntime.build(cfg_off)
    ref_sched = Scheduler(cfg_off, rt_off, params, 1, 32)
    ref = Request(rid=0, prompt=[1, 2, 3], max_new=3)
    ref_sched.submit(ref)
    _drain(ref_sched)
    assert req.out == ref.out


# ----------------------------------------------- scheduler edge cases
def test_empty_prompt_decodes_from_token_zero(lm):
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, 1, 32)
    req = Request(rid=0, prompt=[], max_new=2)
    sched.submit(req)
    _drain(sched)
    assert req.done and req.error is None and len(req.out) == 2


def test_prompt_past_max_len_rejected_at_submit(lm):
    """Previously a livelock: the slot could never finish.  Now it is a
    clear error before the request ever holds a slot."""
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, 1, 8)
    with pytest.raises(ValueError, match="cannot fit max_len"):
        sched.submit(Request(rid=0, prompt=list(range(8)), max_new=1))
    with pytest.raises(ValueError, match="max_new"):
        sched.submit(Request(rid=1, prompt=[1], max_new=0))
    # near the cap is fine — and terminates (pos-cap applies mid-prompt)
    req = Request(rid=2, prompt=list(range(7)), max_new=4)
    sched.submit(req)
    _drain(sched)
    assert req.done


def test_fifo_admission_order_under_slot_contention(lm):
    """One slot, three requests: completion follows submission order."""
    cfg, rt, params = lm
    sched = Scheduler(cfg, rt, params, 1, 32)
    done_order = []
    reqs = [Request(rid=i, prompt=[i + 1], max_new=2) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    while sched.step():
        for r in reqs:
            if r.done and r.rid not in done_order:
                done_order.append(r.rid)
    assert done_order == [0, 1, 2]


# -------------------------------------------- launcher arg validation
def test_launchers_reject_bad_amm_args_at_parse_time():
    from repro.launch.serve import main as serve_main
    from repro.launch.train import main as train_main
    bad = [["--amm", "bitexact", "--vbl", "16"],          # vbl >= wl
           ["--amm", "bitexact", "--wl", "18"],           # wl out of range
           ["--amm", "bitexact", "--wl", "7"],            # odd wl
           ["--amm", "bitexact", "--vbl", "-1"],
           ["--amm", "noise", "--mul", "madeup"]]         # unknown kind
    for argv in bad:
        with pytest.raises(SystemExit):
            serve_main(["--reduced"] + argv)
        with pytest.raises(SystemExit):
            train_main(["--reduced", "--steps", "1"] + argv)


def test_serve_launcher_rejects_kv_codes_without_booth_attention():
    """--kv-codes stores Booth attention codes: anything short of a
    bitexact Booth-family amm with attention routed must die at parse
    time (``launch.validate_serve_flags``), not deep in Scheduler init."""
    from repro.launch.serve import main as serve_main
    bad = [["--kv-codes"],                                     # amm off
           ["--kv-codes", "--amm", "noise", "--amm-attn"],     # not bitexact
           ["--kv-codes", "--amm", "bitexact", "--mul", "bam",
            "--wl", "8", "--vbl", "5", "--amm-attn"],          # non-Booth
           ["--kv-codes", "--amm", "bitexact", "--wl", "8",
            "--vbl", "5"]]                                     # no --amm-attn
    for argv in bad:
        with pytest.raises(SystemExit):
            serve_main(["--reduced"] + argv)
