"""Flash-amm contract suite: the Broken-Booth datapath inside the flash
online-softmax tile arithmetic (kernels/flash_attention.py).

The load-bearing claim is *bitwise* equality against the chunked-amm
schedule at matched head counts and tile sizes
(``models.attention.flash_amm_chunked_equiv``): quantization is per
block, so same blocking + same quantizer + same float op order must give
``assert_array_equal``, not allclose.  Both lowerings of the shared tile
step are held to it — the Pallas kernel (interpret mode on CPU CI) and
the fused XLA scan that serves as the off-TPU fast path — across
wl x vbl x kind with envelope-edge operands, causal and noncausal
masking, and a padded (ragged) final KV block.  Routing pins: amm-active
``use_pallas`` selects flash-amm, ``apply_to="mlp"`` still selects
exact-flash, and falling off the flash path emits a structured
``FlashFallbackWarning``.  Gradients: the flash-amm ``custom_vjp``
backward is the chunked path's straight-through rule, bit-identical.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AmmConfig, get_arch, reduced
from repro.core.multipliers import MulSpec
from repro.kernels.bbm_matmul import bbm_matmul_scaled, dot_scaled_chunked
from repro.kernels.booth_rows import (amm_chunk_len, booth_precode,
                                      f32_exact_chunk_len)
from repro.kernels.flash_attention import flash_attention_amm
from repro.kernels.ref import (AMM_BOOTH_KINDS, amm_effective_vbl,
                               amm_flash_attention_ref, amm_quantize)
from repro.models import attention as attention_mod
from repro.models.attention import (FlashFallbackWarning, attention,
                                    attn_table, flash_amm_chunked_equiv,
                                    reset_flash_fallback_dedup)
from repro.models.common import AmmRuntime, init_params

RNG = np.random.default_rng(31)


@pytest.fixture(autouse=True)
def _fresh_fallback_dedup():
    # fallback warnings dedup per (reason, call-site): without a reset,
    # whichever test warns first would swallow every later test's warning
    reset_flash_fallback_dedup()
    yield
    reset_flash_fallback_dedup()

# same Booth-family cells as tests/test_amm_attention.py: both word
# lengths x both truncation kinds, the exact multiplier (vbl=0), and the
# single-digit-chunk point (16, 3) whose products cross chunk boundaries
SWEEP = [("bbm0", 8, 5), ("bbm1", 8, 7), ("bbm0", 12, 7), ("bbm1", 12, 11),
         ("bbm0", 16, 13), ("bbm1", 16, 15), ("bbm0", 16, 3),
         ("booth", 16, 0)]


def _rt(mul, wl, vbl, apply_to="all", mode="bitexact"):
    return AmmRuntime.build(AmmConfig(mode=mode, mul=mul, wl=wl, param=vbl,
                                      apply_to=apply_to))


def _lowering(mul, wl, vbl):
    return wl, (0 if mul == "booth" else vbl), AMM_BOOTH_KINDS[mul]


def _qkv(b=1, h=2, sq=40, skv=40, d=16, seed=3):
    """(B, H, S, D) operands with envelope-edge rows (quantize to +lim)."""
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((b, h, sq, d)).astype(np.float32)
    k = rng.standard_normal((b, h, skv, d)).astype(np.float32)
    v = rng.standard_normal((b, h, skv, d)).astype(np.float32)
    q[0, 0, 0, :] = np.abs(q).max() * 1.5
    k[0, 0, 0, :] = np.abs(k).max() * 1.5
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def _chunked_ref(q, k, v, rt, *, causal, bq, bk):
    """Chunked-amm at explicit tile sizes, (B, H, S, D) layout."""
    from repro.models.attention import chunked_attention
    out = chunked_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=causal,
                            bq=bq, bk=bk, amm=rt)
    return out.transpose(0, 2, 1, 3)


# ------------------------------------------------- in-kernel correction
@pytest.mark.parametrize("mul,wl,vbl", SWEEP)
def test_dot_scaled_chunked_matches_scaled(mul, wl, vbl):
    """The kernel-safe chunked contraction (static python loop, optional
    exact-f32-envelope gemms) == the jitted scan entry point, bitwise,
    for single- and multi-chunk K."""
    wl_, vbl_, kind = _lowering(mul, wl, vbl)
    rng = np.random.default_rng(17)
    chunk = amm_chunk_len(wl_, vbl_)
    for kk in (16, min(2 * chunk + 5, 200)):
        a = rng.standard_normal((8, kk)).astype(np.float32)
        b = rng.standard_normal((kk, 12)).astype(np.float32)
        a[0, :] = np.abs(a).max() * 1.5
        aq, _ = amm_quantize(jnp.asarray(a), wl_)
        bq, _ = amm_quantize(jnp.asarray(b), wl_)
        mag, neg = booth_precode(bq, wl_)
        ref = bbm_matmul_scaled(aq, mag, neg, wl=wl_, vbl=vbl_, kind=kind)
        for f32_dots in (False, True):
            got = dot_scaled_chunked(aq, mag, neg, wl=wl_, vbl=vbl_,
                                     kind=kind, f32_dots=f32_dots)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_f32_exact_envelope_is_tighter_than_int32():
    """The f32 chunk is a subset of the int32 chunk (budget 2^24 vs
    2^31-1) and vanishes exactly where one product already overflows it."""
    for wl in (8, 12, 16):
        for vbl in range(0, wl):
            assert f32_exact_chunk_len(wl, vbl) <= amm_chunk_len(wl, vbl)
    assert f32_exact_chunk_len(16, 6) == 0      # 2^(31-6) > 2^24: no envelope
    assert f32_exact_chunk_len(16, 13) > 0
    assert f32_exact_chunk_len(8, 5) > 0


# --------------------------------------------------- bitwise equality
@pytest.mark.parametrize("mul,wl,vbl", SWEEP)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_flash_amm_matches_chunked(mul, wl, vbl, use_kernel):
    """The headline contract: flash-amm == chunked-amm bitwise at matched
    tiles, for both lowerings of the tile step.  S=40 with 16-wide tiles
    also exercises the padded (ragged) final Q and KV blocks."""
    wl_, vbl_, kind = _lowering(mul, wl, vbl)
    q, k, v = _qkv()
    ref = _chunked_ref(q, k, v, _rt(mul, wl, vbl), causal=True, bq=16, bk=16)
    got = flash_attention_amm(q, k, v, wl=wl_, vbl=vbl_, kind=kind,
                              causal=True, bq=16, bk=16,
                              use_kernel=use_kernel,
                              interpret=True if use_kernel else None)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("causal", [True, False])
def test_flash_amm_noncausal_and_ragged_kv(causal):
    """Rectangular Sq != Skv with a partial final KV block (skv=25,
    bk=16): the masking and explicit zero-padding must agree with the
    chunked path under both masks."""
    q, k, v = _qkv(sq=12, skv=25)
    rt = _rt("bbm0", 16, 13)
    ref = _chunked_ref(q, k, v, rt, causal=causal, bq=16, bk=16)
    for use_kernel in (False, True):
        got = flash_attention_amm(q, k, v, wl=16, vbl=13, kind=0,
                                  causal=causal, bq=16, bk=16,
                                  use_kernel=use_kernel,
                                  interpret=True if use_kernel else None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_flash_amm_matches_scalar_oracle():
    """Transitivity check at default (128) tiles: flash-amm == the scalar
    closed-form oracle ``amm_flash_attention_ref`` (which runs the
    chunked schedule with every product through ``core.multipliers``)."""
    q, k, v = _qkv(sq=24, skv=24)
    for mul, wl, vbl in (("bbm0", 16, 13), ("bbm1", 8, 7)):
        wl_, vbl_, kind = _lowering(mul, wl, vbl)
        got = flash_attention_amm(q, k, v, wl=wl_, vbl=vbl_, kind=kind,
                                  causal=True, use_kernel=False)
        ref = amm_flash_attention_ref(q, k, v, MulSpec(mul, wl, vbl),
                                      causal=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_flash_amm_decode_shape_smoke():
    """A single-query call (the decode tile shape, bq=1) runs on both
    lowerings and matches the chunked path bitwise."""
    q, k, v = _qkv(sq=1, skv=33)
    rt = _rt("bbm0", 16, 13)
    ref = _chunked_ref(q, k, v, rt, causal=False, bq=16, bk=16)
    for use_kernel in (False, True):
        got = flash_attention_amm(q, k, v, wl=16, vbl=13, kind=0,
                                  causal=False, bq=16, bk=16,
                                  use_kernel=use_kernel,
                                  interpret=True if use_kernel else None)
        assert got.shape == q.shape
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ------------------------------------------------------------- routing
def _attn_setup(apply_to):
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, amm=AmmConfig(mode="bitexact", mul="bbm0",
                                                 wl=16, param=13,
                                                 apply_to=apply_to))
    p = init_params(attn_table(cfg), jax.random.key(0))
    x = jnp.asarray(RNG.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    positions = jnp.arange(16)[None, :] * jnp.ones((2, 1), jnp.int32)
    return cfg, p, x, positions, AmmRuntime.build(cfg.amm)


def test_apply_to_mlp_still_selects_exact_flash(monkeypatch):
    """Routing pin: under apply_to="mlp" attention is not amm-active, the
    transformer gate passes amm=None, and use_pallas selects the *exact*
    flash kernel — bit-identical to an explicit amm=None call, with the
    flash-amm route never entered."""
    cfg, p, x, positions, rt = _attn_setup("mlp")
    assert rt.attn_active is False
    gated = rt if rt.attn_active else None     # the transformer's gate

    entered = []
    orig = attention_mod._flash_amm_ste
    monkeypatch.setattr(
        attention_mod, "_flash_amm_ste",
        lambda *a: (entered.append(True), orig(*a))[1])
    y_gated, _ = attention(p, x, cfg, positions=positions, use_pallas=True,
                           amm=gated)
    y_exact, _ = attention(p, x, cfg, positions=positions, use_pallas=True,
                           amm=None)
    assert not entered
    np.testing.assert_array_equal(np.asarray(y_gated), np.asarray(y_exact))


def test_ste_gradient_parity_with_chunked():
    """The flash-amm backward *is* the chunked path's straight-through
    gradient (custom_vjp over ``flash_amm_chunked_equiv``): grads agree
    bitwise, and the forwards they differentiate are bitwise equal too."""
    from repro.models.attention import _flash_amm_ste
    q, k, v = _qkv(sq=24, skv=24, d=8)
    rt = _rt("bbm0", 16, 13)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.square(_flash_amm_ste(rt, True, q, k, v)))

    def loss_chunked(q, k, v):
        return jnp.sum(jnp.square(
            flash_amm_chunked_equiv(q, k, v, rt, causal=True)))

    lf, gf = jax.value_and_grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    lc, gc = jax.value_and_grad(loss_chunked, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lc))
    for a, b in zip(gf, gc):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.isfinite(np.asarray(a)).all()


# --------------------------------------------------- fallback warnings
def test_seq_cap_fallback_warns_with_context(monkeypatch):
    """Above the flash sequence cap the call lands on the chunked path
    with a FlashFallbackWarning naming shape, amm state and cap — not
    silently (the old behavior this replaces)."""
    cfg, p, x, positions, rt = _attn_setup("all")
    monkeypatch.setattr(attention_mod, "_FLASH_SEQ_CAP", 8)
    with pytest.warns(FlashFallbackWarning) as rec:
        y_pl, _ = attention(p, x, cfg, positions=positions, use_pallas=True,
                            amm=rt)
    msg = str(rec[0].message)
    assert "cap" in msg and "seq=16" in msg and "bbm0" in msg
    y_js, _ = attention(p, x, cfg, positions=positions, use_pallas=False,
                        amm=rt)
    np.testing.assert_array_equal(np.asarray(y_pl), np.asarray(y_js))


def test_no_lowering_fallback_warns(monkeypatch):
    """An amm runtime without a dot-form lowering (mode="noise") cannot
    ride the flash path: warn with the family/mode, fall back chunked."""
    cfg, p, x, positions, _ = _attn_setup("all")
    rt = _rt("bbm0", 16, 13, mode="noise")
    assert rt.attn_lowering is None
    with pytest.warns(FlashFallbackWarning, match="no flash lowering"):
        y_pl, _ = attention(p, x, cfg, positions=positions, use_pallas=True,
                            amm=rt)
    y_js, _ = attention(p, x, cfg, positions=positions, use_pallas=False,
                        amm=rt)
    np.testing.assert_array_equal(np.asarray(y_pl), np.asarray(y_js))


def test_fallback_warning_deduplicated_per_site(monkeypatch):
    """The same fallback from the same call site warns exactly once — a
    decode loop hitting the cap every step says it one time, not per
    token.  A different reason (or a reset) warns again."""
    cfg, p, x, positions, rt = _attn_setup("all")
    monkeypatch.setattr(attention_mod, "_FLASH_SEQ_CAP", 8)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(3):   # same site, same reason: one warning
            attention(p, x, cfg, positions=positions, use_pallas=True,
                      amm=rt)
    fall = [w for w in rec if issubclass(w.category, FlashFallbackWarning)]
    assert len(fall) == 1
    reset_flash_fallback_dedup()
    with pytest.warns(FlashFallbackWarning):   # reset: the site warns again
        attention(p, x, cfg, positions=positions, use_pallas=True, amm=rt)


def test_in_cap_flash_route_does_not_warn():
    """The happy path emits nothing — the warning is a fallback signal,
    not a use_pallas tax."""
    cfg, p, x, positions, rt = _attn_setup("all")
    with warnings.catch_warnings():
        warnings.simplefilter("error", FlashFallbackWarning)
        attention(p, x, cfg, positions=positions, use_pallas=True, amm=rt)
