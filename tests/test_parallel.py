"""Distribution-layer tests: logical rules, shape-aware sharding, and a
multi-device (8 forced host devices) subprocess exercising shard_map
compressed all-reduce and a 2x4 mesh train step."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.logical import (OPT_RULES_MULTIPOD, RULES,
                                    RULES_MULTIPOD, batch_pspec,
                                    spec_to_pspec)


def test_rules_basic():
    assert spec_to_pspec(("embed", "mlp"), RULES) == P("data", "model")
    assert spec_to_pspec(("vocab", "embed"), RULES) == P("model", "data")
    assert spec_to_pspec(("layers", "embed", "heads"), RULES) == \
        P(None, "data", "model")


def test_rules_no_duplicate_mesh_axis():
    # experts takes model; mlp inside the expert must fall back to None
    got = spec_to_pspec(("experts", "embed", "expert_mlp"), RULES)
    assert got == P("model", "data", None)
    got2 = spec_to_pspec(("heads", "kv_heads"), RULES)
    assert got2 == P("model", None)


def test_rules_multipod_batch():
    assert spec_to_pspec(("batch", "seq"), RULES_MULTIPOD) == \
        P(("pod", "data"), None)
    assert spec_to_pspec(("embed", "mlp"), OPT_RULES_MULTIPOD) == \
        P(("pod", "data"), "model")


def test_divisibility_dropping():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}
    fm = FakeMesh()
    # 14 heads on a 16-way axis -> replicated
    got = spec_to_pspec(("embed", "heads"), RULES, shape=(896, 14), mesh=fm)
    assert got == P("data", None)
    # divisible stays sharded
    got = spec_to_pspec(("embed", "heads"), RULES, shape=(896, 64), mesh=fm)
    assert got == P("data", "model")
    # multipod batch of 1 -> fully replicated
    got = spec_to_pspec(("batch",), RULES_MULTIPOD, shape=(1,), mesh=fm)
    assert got == P(None)
    # batch 32 divisible by pod*data=32
    got = spec_to_pspec(("batch",), RULES_MULTIPOD, shape=(32,), mesh=fm)
    assert got == P(("pod", "data"))


MULTIDEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((4, 2), ("data", "model"))

# --- compressed allreduce over the data axis
from repro.parallel.compress import compressed_allreduce, allreduce_ref
rng = np.random.default_rng(0)
g = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
sharded = jax.device_put(g, NamedSharding(mesh, P("data", None)))
means, errs = compressed_allreduce({"g": sharded}, mesh, codec="int8")
ref = np.mean(np.asarray(g).reshape(4, 1, 64), axis=0)  # mean over shards
got = np.asarray(means["g"])
# each shard's row equals the mean of all shards' rows (approximately)
err = float(np.abs(got - np.broadcast_to(ref, got.shape)).max())
assert err < 0.05, err

# --- channel-sharded filterbank: 8 channels over 8 data shards
from repro.parallel.filterbank import sharded_filterbank
from repro.kernels.ref import fir_bank_ref
mesh1 = jax.make_mesh((8,), ("data",))
xc = jnp.asarray(rng.integers(0, 1 << 12, (8, 256)), jnp.int32)
hc = jnp.asarray(rng.integers(0, 1 << 12, (8, 31)), jnp.int32)
got_fb = sharded_filterbank(xc, hc, mesh1, wl=12, vbl=9, kind=1)
ref_fb = fir_bank_ref(xc, hc, wl=12, vbl=9, kind=1)
assert np.array_equal(np.asarray(got_fb), np.asarray(ref_fb))
try:
    sharded_filterbank(xc[:6], hc[:6], mesh1, wl=12, vbl=9)
    raise SystemExit("divisibility guard did not fire")
except ValueError:
    pass

# --- tiny train step on a real 4x2 mesh
from repro.configs import get_arch, reduced
from repro.models import ModelRuntime
from repro.train.trainstep import TrainConfig, make_train_step, init_train_state
from repro.train.optimizer import OptConfig
cfg = reduced(get_arch("llama3.2-3b"))
rt = ModelRuntime.build(cfg)
tc = TrainConfig(microbatches=2, opt=OptConfig(lr=1e-3, total_steps=10))
step = make_train_step(cfg, rt, tc, mesh, global_batch=8)
params, opt = init_train_state(cfg, tc, mesh, jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)
labels = jnp.roll(toks, -1, axis=-1)
l0 = None
for i in range(3):
    params, opt, metrics = step(params, opt, toks, labels,
                                jax.random.fold_in(jax.random.key(2), i))
    if l0 is None:
        l0 = float(metrics["loss"])
l1 = float(metrics["loss"])
assert np.isfinite(l1)
assert l1 < l0          # overfits the fixed batch
print(json.dumps({"ok": True, "l0": l0, "l1": l1, "int8_err": err,
                  "filterbank_ok": True}))
"""


def test_multidevice_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["l1"] < res["l0"]
