"""Exact-dot + low-bit-correction lowering tests.

The dot form's contract is one algebraic identity and three layers of
bit-exact plumbing on top of it:

  * identity — ``bbm_mul(a, b) == a_s*b_s - booth_correction(a, planes)``
    for every wl x vbl x kind, including vbl = 0 (zero correction), the
    Type-1 "negative zero" 111 triplet, and the extreme operands at
    +/-2^(wl-1).  Checked exhaustively at wl = 8, on targeted edge grids
    at wl = 12/16, and property-based via hypothesis.
  * kernels — ``form="dot"`` is bit-identical to ``form="rows"`` (and to
    the pure-jnp oracles) for the FIR filterbank and the matmul, across
    the sweep, shifts included.
  * envelope — the dot form accumulates exact products before subtracting
    the correction, so its int32 analysis is re-derived
    (``dotform_scaled_bound``): every BBM product is divisible by
    ``2^vbl``, and accumulating at that scale keeps the dot form inside
    the rows-form envelope for *every* vbl — including contraction sizes
    the rows envelope admits only barely.
  * dsp / serve / parallel — ``fir_apply(form=...)``, the engine and the
    sharded filterbank pick the dot form automatically and stay
    bit-identical to the rows datapath.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bbm import bbm_mul
from repro.core.booth import to_signed
from repro.kernels import (bbm_matmul_precoded, bbm_rows_product_dotform,
                           booth_correction, booth_high_value, booth_precode,
                           booth_value, dotform_scaled_bound,
                           fir_bbm_bank_precoded, min_safe_shift,
                           resolve_form)
from repro.kernels.booth_rows import num_corr_rows, split_signed
from repro.kernels.ref import bbm_matmul_ref, fir_bank_ref

RNG = np.random.default_rng(23)

# (wl, vbl) sweep points; kind 0/1 covers bbm0/bbm1
SWEEP = [(8, 0), (8, 5), (12, 7), (12, 11), (16, 13), (16, 15)]


def _identity_check(a, b, wl, vbl, kind):
    """bbm_mul == exact product minus correction, elementwise."""
    _, a_s = split_signed(a, wl)
    mag, neg = booth_precode(b, wl)
    ref = np.asarray(bbm_mul(a, b, wl, vbl, kind=kind), np.int64)
    exact = np.asarray(a_s, np.int64) * np.asarray(to_signed(b, wl), np.int64)
    corr = np.asarray(booth_correction(a_s, mag, neg, wl=wl, vbl=vbl,
                                       kind=kind), np.int64)
    np.testing.assert_array_equal(ref, exact - corr)
    # correction is nonnegative and narrow: bounded by R * 2^vbl per row sum
    assert corr.min() >= 0
    assert corr.max() <= num_corr_rows(wl, vbl) * (1 << vbl)
    # and the packaged third form agrees too
    got = np.asarray(bbm_rows_product_dotform(a_s, mag, neg, wl=wl, vbl=vbl,
                                              kind=kind), np.int64)
    np.testing.assert_array_equal(ref, got)


# ------------------------------------------------------------- the identity
@pytest.mark.parametrize("vbl", [0, 1, 5, 7])
@pytest.mark.parametrize("kind", [0, 1])
def test_identity_exhaustive_wl8(vbl, kind):
    """All 2^16 operand pairs at wl = 8: the identity has no exceptions."""
    wl = 8
    codes = jnp.arange(1 << wl, dtype=jnp.int32)
    a, b = jnp.meshgrid(codes, codes)
    _identity_check(a.ravel(), b.ravel(), wl, vbl, kind)


@pytest.mark.parametrize("wl,vbl", [(12, 7), (12, 11), (16, 13), (16, 15)])
@pytest.mark.parametrize("kind", [0, 1])
def test_identity_edge_operands(wl, vbl, kind):
    """Extremes (+/-2^(wl-1)), zero, and all-ones / 111-triplet patterns.

    The code ``1 << (wl - 1)`` is the most negative operand -2^(wl-1);
    ``(1 << wl) - 1`` is -1, whose Booth digits are all 111 "negative
    zero" triplets (mag 0, neg 1) — the row Type1 truncation exposes.
    """
    top = 1 << (wl - 1)
    edges = [0, 1, 2, top - 1, top, top + 1, (1 << wl) - 1,
             0b111 << (wl - 4), (1 << wl) - 2, top >> 1]
    rnd = RNG.integers(0, 1 << wl, 32).tolist()
    codes = jnp.asarray(sorted(set(edges + rnd)), jnp.int32)
    a, b = jnp.meshgrid(codes, codes)
    _identity_check(a.ravel(), b.ravel(), wl, vbl, kind)


@pytest.mark.parametrize("wl,vbl", SWEEP)
@pytest.mark.parametrize("kind", [0, 1])
@settings(deadline=None, max_examples=50)
@given(a=st.integers(0, (1 << 16) - 1), b=st.integers(0, (1 << 16) - 1))
def test_identity_property(wl, vbl, kind, a, b):
    """Hypothesis sweep: bbm_mul(a, b) == a*b - correction(a_low, digits)."""
    a = jnp.asarray([a & ((1 << wl) - 1)], jnp.int32)
    b = jnp.asarray([b & ((1 << wl) - 1)], jnp.int32)
    _identity_check(a, b, wl, vbl, kind)


def test_vbl0_correction_is_zero():
    """vbl = 0: no break line, the dot form is a pure exact contraction."""
    wl = 12
    a = jnp.asarray(RNG.integers(0, 1 << wl, 512), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 1 << wl, 512), jnp.int32)
    _, a_s = split_signed(a, wl)
    mag, neg = booth_precode(b, wl)
    for kind in (0, 1):
        corr = np.asarray(booth_correction(a_s, mag, neg, wl=wl, vbl=0,
                                           kind=kind))
        assert not corr.any()
    np.testing.assert_array_equal(
        np.asarray(booth_value(mag, neg, wl=wl)), np.asarray(to_signed(b, wl)))
    # with no break line every digit row "survives": bq is the multiplier
    np.testing.assert_array_equal(
        np.asarray(booth_high_value(mag, neg, wl=wl, vbl=0)),
        np.asarray(to_signed(b, wl)))


# ------------------------------------------------------------- kernel level
@pytest.mark.parametrize("wl,vbl", SWEEP)
@pytest.mark.parametrize("kind", [0, 1])
def test_fir_kernel_dot_vs_rows(wl, vbl, kind):
    """form="dot" == form="rows" == oracle for the FIR filterbank."""
    channels, n, taps = 4, 384, 31
    shift = min_safe_shift(taps, wl)
    x = jnp.asarray(RNG.integers(0, 1 << wl, (channels, n)), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, (channels, taps)), jnp.int32)
    hmag, hneg = booth_precode(h, wl)
    ref = fir_bank_ref(x, h, wl=wl, vbl=vbl, kind=kind, shift=shift)
    dot = fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                shift=shift, form="dot")
    rows = fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                 shift=shift, bc=2, bt=128, interpret=True,
                                 form="rows")
    np.testing.assert_array_equal(np.asarray(dot), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(ref))
    # the accelerator contraction layout (windowed dot_general / im2col)
    # must agree too — `windowed=True` forces it on CPU so the branch that
    # actually runs on TPU is exercised by this CI
    win = fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                shift=shift, form="dot", windowed=True)
    np.testing.assert_array_equal(np.asarray(win), np.asarray(ref))
    # auto (form=None) must resolve to one of the two, never a third thing
    auto = fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                 shift=shift, bt=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))


@pytest.mark.parametrize("wl,vbl", [(8, 5), (12, 7), (16, 13), (16, 15)])
@pytest.mark.parametrize("kind", [0, 1])
def test_matmul_dot_vs_rows(wl, vbl, kind):
    """x @ w - correction == the rows kernel == closed-form accumulation."""
    m, k, n = 8, 32, 8
    shift = min_safe_shift(k, wl)
    x = jnp.asarray(RNG.integers(0, 1 << wl, (m, k)), jnp.int32)
    w = jnp.asarray(RNG.integers(0, 1 << wl, (k, n)), jnp.int32)
    wmag, wneg = booth_precode(w, wl)
    ref = bbm_matmul_ref(x, w, wl=wl, vbl=vbl, kind=kind, shift=shift)
    dot = bbm_matmul_precoded(x, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                              shift=shift, form="dot")
    rows = bbm_matmul_precoded(x, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                               shift=shift, bm=8, bk=16, bn=8,
                               interpret=True, form="rows")
    np.testing.assert_array_equal(np.asarray(dot), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(ref))


@pytest.mark.parametrize("kind", [0, 1])
def test_residual_shift_with_truncated_rows(kind):
    """0 < vbl < shift: the per-product ``>> (shift - vbl)`` residual.

    The floor applies to each scaled product (truncated rows included)
    *before* the tap/K reduction — a sum-then-shift rewrite would pass
    every other sweep point (they all have vbl = 0 or vbl >= shift) but
    produce wrong bits here.
    """
    wl, vbl, shift = 16, 3, 6
    x = jnp.asarray(RNG.integers(0, 1 << wl, (3, 257)), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, (3, 31)), jnp.int32)
    hmag, hneg = booth_precode(h, wl)
    ref = fir_bank_ref(x, h, wl=wl, vbl=vbl, kind=kind, shift=shift)
    dot = fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                shift=shift, form="dot")
    np.testing.assert_array_equal(np.asarray(dot), np.asarray(ref))
    m, k, n = 5, 32, 5
    xm = jnp.asarray(RNG.integers(0, 1 << wl, (m, k)), jnp.int32)
    w = jnp.asarray(RNG.integers(0, 1 << wl, (k, n)), jnp.int32)
    wmag, wneg = booth_precode(w, wl)
    refm = bbm_matmul_ref(xm, w, wl=wl, vbl=vbl, kind=kind, shift=shift)
    dotm = bbm_matmul_precoded(xm, wmag, wneg, wl=wl, vbl=vbl, kind=kind,
                               shift=shift, form="dot")
    np.testing.assert_array_equal(np.asarray(dotm), np.asarray(refm))


def test_fir_dot_shift_zero_and_unaligned_shapes():
    """No rescale (shift = 0) and odd C/N exercise the non-padded path."""
    wl, vbl, kind = 12, 9, 1
    x = jnp.asarray(RNG.integers(0, 1 << wl, (3, 333)), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, (3, 31)), jnp.int32)
    hmag, hneg = booth_precode(h, wl)
    ref = fir_bank_ref(x, h, wl=wl, vbl=vbl, kind=kind)
    dot = fir_bbm_bank_precoded(x, hmag, hneg, wl=wl, vbl=vbl, kind=kind,
                                form="dot")
    np.testing.assert_array_equal(np.asarray(dot), np.asarray(ref))


# ----------------------------------------------------- re-derived envelope
def test_dotform_scaled_bound_never_looser_than_rows():
    """The re-derived analysis: scaled accumulation <= rows envelope.

    Naively, "accumulate exact products then subtract the correction"
    needs ``k * 2^(2wl-1)`` of headroom — hopeless in int32 at wl = 16.
    The folded form accumulates ``bbm / 2^max(vbl, shift)`` instead, and
    its worst case is never larger than the rows form's, for every vbl.
    """
    for k in (31, 64, 1024, 4096):
        for wl in (8, 12, 16):
            for shift in range(0, 14):
                rows_bound = k * 2 ** max(2 * wl - 1 - shift, 0)
                for vbl in range(0, 2 * wl - 6 if wl >= 14 else wl):
                    assert dotform_scaled_bound(k, wl, vbl, shift)                         <= rows_bound
    assert resolve_form(None) == "dot" == resolve_form("dot")
    assert resolve_form("rows") == "rows"
    with pytest.raises(ValueError, match="form"):
        resolve_form("mxu")


def test_dot_form_safe_at_rows_envelope_boundary():
    """Operating points the rows envelope barely admits stay bit-exact.

    taps=64/wl=16/shift=7 sits one power of two inside the int32 line
    (64 * 2^(31-7) == 2^30), with all-extreme operands (-2^15 codes)
    driving every product to its +2^30 maximum; the int64 numpy oracle
    confirms the scaled dot accumulation never wrapped.  K=4096 at
    shift=13 is a contraction the exact-product sum could never survive
    unscaled (4096 * 2^31 >> 2^31).
    """
    wl, taps, shift = 16, 64, 7
    top = jnp.int32(1 << (wl - 1))          # the -2^15 code
    x = jnp.full((2, 200), top, jnp.int32)
    h = jnp.full((2, taps), top, jnp.int32)
    hmag, hneg = booth_precode(h, wl)
    for vbl, kind in [(0, 0), (13, 0), (13, 1), (15, 1)]:
        dot = np.asarray(fir_bbm_bank_precoded(
            x, hmag, hneg, wl=wl, vbl=vbl, kind=kind, shift=shift,
            form="dot"), np.int64)
        prod = np.asarray(bbm_mul(
            _window_np(np.asarray(x), taps), np.asarray(h)[:, None, :],
            wl, vbl, kind=kind), np.int64)
        ref = np.sum(prod >> shift, axis=-1)
        np.testing.assert_array_equal(dot, ref, err_msg=f"vbl={vbl}")
    # huge-K matmul: rows envelope needs shift=13; the dot form holds too
    k = 4096
    xm = jnp.full((2, k), top, jnp.int32)
    w = jnp.full((k, 3), top, jnp.int32)
    wmag, wneg = booth_precode(w, wl)
    dot = np.asarray(bbm_matmul_precoded(xm, wmag, wneg, wl=wl, vbl=13,
                                         shift=13, form="dot"), np.int64)
    prod = np.asarray(bbm_mul(xm[:, :, None], w[None], wl, 13), np.int64)
    np.testing.assert_array_equal(dot, np.sum(prod >> 13, axis=1))


def _window_np(x, taps):
    """win[c, n, k] = x[c, n-k] with zero codes before the signal."""
    n = x.shape[-1]
    idx = np.arange(n)[:, None] - np.arange(taps)[None, :]
    return np.where(idx >= 0, x[..., np.clip(idx, 0, None)], 0)


# -------------------------------------------------------- dsp / serve level
def test_fir_apply_forms_bit_exact():
    scipy = pytest.importorskip("scipy")  # noqa: F841  (design_lowpass)
    from repro.core.multipliers import MulSpec
    from repro.dsp import design_lowpass, fir_apply
    x = RNG.standard_normal((4, 400))
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    h = banks[[0, 1, 1, 0]]
    for name, wl, vbl in [("bbm0", 16, 13), ("bbm1", 16, 13),
                          ("bbm0", 12, 7), ("booth", 16, 0)]:
        spec = MulSpec(name, wl, vbl)
        ref = fir_apply(x, h, spec, backend="host", form="rows")
        for backend in ("host", "pallas-interpret"):
            for form in ("dot", None):
                got = fir_apply(x, h, spec, backend=backend, form=form,
                                block=128, bc=2)
                np.testing.assert_array_equal(ref, got,
                                              err_msg=f"{spec} {backend} "
                                                      f"{form}")


def test_fir_apply_rejects_dot_off_the_hot_path():
    scipy = pytest.importorskip("scipy")  # noqa: F841
    from repro.core.multipliers import MulSpec
    from repro.dsp import design_lowpass, fir_apply
    x = RNG.standard_normal(64)
    h = design_lowpass()
    with pytest.raises(ValueError, match="dot"):
        fir_apply(x, h, MulSpec("bam", 8, 2), backend="host", form="dot")
    with pytest.raises(ValueError, match="dot"):
        fir_apply(x, h, MulSpec("bbm0", 16, 13), backend="host",
                  datapath="wlbit", shift=0, form="dot")
    with pytest.raises(ValueError, match="form"):
        fir_apply(x, h, MulSpec("bbm0", 16, 13), form="mxu")


def test_engine_and_sharded_pick_dot_automatically():
    scipy = pytest.importorskip("scipy")  # noqa: F841
    from repro.core.multipliers import MulSpec
    from repro.dsp import design_lowpass
    from repro.parallel import precode_filterbank, sharded_filterbank
    from repro.serve import FilterbankEngine

    # serving: rows-form engine == dot-form engine == auto engine, request
    # by request
    banks = np.stack([design_lowpass(), design_lowpass(stop_weight=0.5)])
    spec = MulSpec("bbm0", 16, 13)
    sigs = [RNG.standard_normal(n) for n in (250, 180, 250)]
    outs = {}
    for form in ("rows", "dot", None):
        eng = FilterbankEngine(banks, spec, backend="host", max_channels=4,
                               form=form)
        rids = [eng.submit(s, bank=i % 2) for i, s in enumerate(sigs)]
        outs[form] = eng.flush()
        assert sorted(outs[form]) == sorted(rids)
    for rid in outs["rows"]:
        np.testing.assert_array_equal(outs["rows"][rid], outs["dot"][rid])
        np.testing.assert_array_equal(outs["rows"][rid], outs[None][rid])

    # sharded: use_kernel=None resolves to the kernel+dot path off-TPU
    wl, vbl, kind, shift = 16, 13, 1, 5
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(RNG.integers(0, 1 << wl, (4, 256)), jnp.int32)
    h = jnp.asarray(RNG.integers(0, 1 << wl, (4, 31)), jnp.int32)
    ref = fir_bank_ref(x, h, wl=wl, vbl=vbl, kind=kind, shift=shift)
    auto = sharded_filterbank(x, h, mesh, wl=wl, vbl=vbl, kind=kind,
                              shift=shift)
    np.testing.assert_array_equal(np.asarray(auto), np.asarray(ref))
    planes = precode_filterbank(h, wl=wl)
    pinned = sharded_filterbank(x, h, mesh, wl=wl, vbl=vbl, kind=kind,
                                shift=shift, h_planes=planes, form="dot")
    np.testing.assert_array_equal(np.asarray(pinned), np.asarray(ref))
