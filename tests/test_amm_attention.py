"""Oracle/routing suite for approximate attention (apply_to="attn"/"all").

The attention score product ``Q @ K^T`` and value product ``P @ V`` are
activation x activation — no weight side, nothing to precode — so their
Broken-Booth lowering is the both-operands-dynamic dot form
(``kernels.bbm_matmul_dynamic`` via ``models.common.amm_dot``).  This
suite holds that datapath to *bitwise* equality against the scalar
closed-form oracles (``kernels.ref.amm_attention_ref`` /
``amm_decode_attention_ref``) across wl x vbl x kind, pins the
``apply_to`` routing (attention exact under "mlp" — the pre-routing code
path — and MLPs exact under "attn"), checks decode-vs-prefill cache
parity at the LM level, and verifies the flash-amm routing:
``use_pallas`` with amm attention active selects the flash-amm lowering
(kernels/flash_attention.py), bit-identical to the chunked schedule at
the flash tile sizes (the full contract lives in tests/test_flash_amm.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import AmmConfig, get_arch, reduced
from repro.core.multipliers import MulSpec
from repro.kernels.bbm_matmul import bbm_matmul_dynamic
from repro.kernels.ref import (AMM_BOOTH_KINDS, amm_attention_ref,
                               amm_decode_attention_codes_ref,
                               amm_decode_attention_ref, amm_dot_ref)
from repro.models import ModelRuntime, init_cache, lm_apply, lm_init
from repro.models import attention as attention_mod
from repro.models.attention import (attention, attn_table, chunked_attention,
                                    code_cache_dequant, code_cache_update,
                                    decode_attention, decode_attention_codes)
from repro.models.common import AmmRuntime, amm_dot, init_params
from repro.serve.kv_cache import code_dtype, init_code_cache

RNG = np.random.default_rng(29)

# Booth-family cells across word lengths, both truncation kinds, the
# exact multiplier, and the single-digit-chunk operating point (16, 3)
# whose PV product crosses the int32-exact chunk boundary
SWEEP = [("bbm0", 8, 5), ("bbm1", 8, 7), ("bbm0", 12, 7), ("bbm1", 12, 11),
         ("bbm0", 16, 13), ("bbm1", 16, 15), ("bbm0", 16, 3),
         ("booth", 16, 0)]


def _rt(mul, wl, vbl, apply_to="all", mode="bitexact"):
    return AmmRuntime.build(AmmConfig(mode=mode, mul=mul, wl=wl, param=vbl,
                                      apply_to=apply_to))


def _qkv(b=2, sq=16, skv=16, h=4, kv=2, d=8, seed=3):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, kv, d)), jnp.float32)
    return q, k, v


# ------------------------------------------------ product-level oracle
@pytest.mark.parametrize("mul,wl,vbl", SWEEP)
def test_bbm_matmul_dynamic_matches_scalar_oracle(mul, wl, vbl):
    """The both-sides-dynamic entry point == the scalar closed forms,
    including full-scale (envelope-edge) rows/columns."""
    rng = np.random.default_rng(7)
    a = rng.standard_normal((5, 12))
    b = rng.standard_normal((12, 9))
    a[0, :] = np.abs(a).max() * 1.5          # quantizes to +lim everywhere
    b[:, 0] = -np.abs(b).max()
    a, b = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)
    got = np.asarray(bbm_matmul_dynamic(a, b, wl=wl, vbl=vbl,
                                        kind=AMM_BOOTH_KINDS[mul]))
    ref = np.asarray(amm_dot_ref(a, b, MulSpec(mul, wl, vbl)))
    np.testing.assert_array_equal(got, ref)


def test_amm_dot_batched_matches_oracle():
    """Leading batch axes vmap to per-slice dynamic scales on both sides."""
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.standard_normal((2, 3, 5, 12)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((2, 3, 12, 7)), jnp.float32)
    rt = _rt("bbm0", 16, 13)
    np.testing.assert_array_equal(
        np.asarray(amm_dot(a, b, rt)),
        np.asarray(amm_dot(a, b, rt, oracle=True)))


def test_amm_dot_is_ste():
    """Gradients ride the exact batched matmul, not the integer path."""
    rt = _rt("bbm0", 16, 13)
    a = jnp.asarray(RNG.standard_normal((2, 4, 8)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((2, 8, 5)), jnp.float32)
    g1 = jax.grad(lambda x: jnp.sum(amm_dot(x, b, rt)))(a)
    g2 = jax.grad(lambda x: jnp.sum(x @ b))(a)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


# --------------------------------------------- attention-level oracle
@pytest.mark.parametrize("mul,wl,vbl", SWEEP)
def test_chunked_attention_matches_scalar_oracle(mul, wl, vbl):
    q, k, v = _qkv()
    got = chunked_attention(q, k, v, causal=True, bq=8, bk=8,
                            amm=_rt(mul, wl, vbl))
    ref = amm_attention_ref(q, k, v, MulSpec(mul, wl, vbl), causal=True,
                            bq=8, bk=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_chunked_attention_amm_noncausal_and_kvlen():
    """Masking interactions: cross-attention (causal=False) and a traced
    kv_len that dead-zeroes part of the final KV block."""
    q, k, v = _qkv(sq=12, skv=20)
    rt = _rt("bbm0", 16, 13)
    spec = MulSpec("bbm0", 16, 13)
    for causal, kv_len in ((False, None), (True, 13), (False, 13)):
        got = chunked_attention(q, k, v, causal=causal, bq=8, bk=8,
                                kv_len=kv_len, amm=rt)
        ref = amm_attention_ref(q, k, v, spec, causal=causal, bq=8, bk=8,
                                kv_len=kv_len)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("mul,wl,vbl", [("bbm0", 16, 13), ("bbm1", 16, 15),
                                        ("bbm0", 16, 3)])
def test_decode_attention_matches_scalar_oracle(mul, wl, vbl):
    """Single-position decode against a cache with a dead (zero) tail."""
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
    kc = np.zeros((2, 16, 2, 8), np.float32)
    vc = np.zeros((2, 16, 2, 8), np.float32)
    kc[:, :10] = rng.standard_normal((2, 10, 2, 8))
    vc[:, :10] = rng.standard_normal((2, 10, 2, 8))
    kc, vc = jnp.asarray(kc), jnp.asarray(vc)
    got = decode_attention(q, kc, vc, 10, amm=_rt(mul, wl, vbl))
    ref = amm_decode_attention_ref(q, kc, vc, 10, MulSpec(mul, wl, vbl))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_amm_attention_actually_differs_from_exact():
    """The routing is not a no-op: a truncating spec changes the output
    (and the exact Booth spec vbl=0 changes only by quantization)."""
    q, k, v = _qkv()
    exact = np.asarray(chunked_attention(q, k, v, causal=True, bq=8, bk=8))
    approx = np.asarray(chunked_attention(q, k, v, causal=True, bq=8, bk=8,
                                          amm=_rt("bbm0", 16, 13)))
    assert not np.array_equal(exact, approx)
    assert np.max(np.abs(exact - approx)) < 0.05   # still an approximation


# --------------------------------------------------------- flash routing
def test_flash_amm_route_selected_under_amm(monkeypatch):
    """use_pallas with amm attention active selects the flash-amm lowering
    (the old behavior — silently falling back to the chunked path — is
    gone), and its output is bit-identical to the chunked schedule run at
    the flash tile sizes with KV heads repeated (the equality contract;
    tests/test_flash_amm.py sweeps it at the kernel level)."""
    from repro.models.attention import flash_amm_chunked_equiv
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, amm=AmmConfig(mode="bitexact", mul="bbm0",
                                                 wl=16, param=13,
                                                 apply_to="all"))
    p = init_params(attn_table(cfg), jax.random.key(0))
    x = jnp.asarray(RNG.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    positions = jnp.arange(16)[None, :] * jnp.ones((2, 1), jnp.int32)
    rt = AmmRuntime.build(cfg.amm)
    called = []
    orig = attention_mod._flash_amm_ste

    def spy(amm, causal, q, k, v):
        called.append(True)
        return orig(amm, causal, q, k, v)

    monkeypatch.setattr(attention_mod, "_flash_amm_ste", spy)
    y_pl, _ = attention(p, x, cfg, positions=positions, use_pallas=True,
                        amm=rt)
    assert called, "use_pallas + active amm must take the flash-amm route"

    # reference: repeat KV heads (as the route does), then the chunked
    # schedule at flash tiles — bitwise equal per the flash-amm contract
    def chunked_ref(pp, xx, *, positions):
        def fake_flash(amm, causal, q, k, v):
            return flash_amm_chunked_equiv(q, k, v, amm, causal=causal)
        monkeypatch.setattr(attention_mod, "_flash_amm_ste", fake_flash)
        out, _ = attention(pp, xx, cfg, positions=positions,
                           use_pallas=True, amm=rt)
        return out

    y_ref = chunked_ref(p, x, positions=positions)
    np.testing.assert_array_equal(np.asarray(y_pl), np.asarray(y_ref))


# ------------------------------------------------------- apply_to routing
def _lm(apply_to, mode="bitexact"):
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(cfg, amm=AmmConfig(mode=mode, mul="bbm0",
                                                 wl=16, param=13,
                                                 apply_to=apply_to))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    return cfg, rt, params


def test_routing_properties():
    assert _rt("bbm0", 16, 13, "mlp").attn_active is False
    assert _rt("bbm0", 16, 13, "attn").attn_active is True
    assert _rt("bbm0", 16, 13, "all").attn_active is True
    assert _rt("bbm0", 16, 13, "attn").mlp_active is False
    assert _rt("bbm0", 16, 13, "mlp").mlp_active is True
    assert _rt("bbm0", 16, 13, "all").mlp_active is True
    # only the bitexact Booth datapath has an attention lowering
    assert _rt("bbm0", 16, 13, "all", mode="noise").attn_active is False
    assert _rt("bam", 8, 4, "all").attn_active is False
    # noise keeps its historical MLP routing
    assert _rt("bbm0", 16, 13, "all", mode="noise").mlp_active is True


def test_apply_to_validated():
    with pytest.raises(ValueError):
        AmmConfig(apply_to="attention")


def test_apply_to_mlp_keeps_attention_exact(monkeypatch):
    """Regression pin: under apply_to="mlp" the attention layer never
    receives an amm runtime — it executes the identical (pre-routing)
    code path, so "mlp" output is bit-identical to pre-PR behavior by
    construction.  Under "all" the same spy sees the runtime arrive."""
    seen = []
    orig = attention_mod.chunked_attention

    def spy(*args, **kw):
        seen.append(kw.get("amm"))
        return orig(*args, **kw)

    monkeypatch.setattr(attention_mod, "chunked_attention", spy)
    toks = jnp.asarray(RNG.integers(0, 512, (2, 8)), jnp.int32)
    cfg, rt, params = _lm("mlp")
    lm_apply(params, cfg, rt, toks, rng=jax.random.key(2))
    assert seen and all(a is None for a in seen)
    seen.clear()
    cfg, rt, params = _lm("all")
    lm_apply(params, cfg, rt, toks, rng=jax.random.key(2))
    assert seen and all(a is not None for a in seen)


def test_no_dead_plane_cache_under_attn_only_routing():
    """apply_to="attn" routes no weight-side matmul: lm_amm_planes must
    return None instead of building an MLP digit-plane cache nothing
    reads (dead startup work + memory held for the process lifetime)."""
    from repro.models import lm_amm_planes
    cfg, rt, params = _lm("attn")
    assert lm_amm_planes(cfg, rt.amm, params) is None
    cfg, rt, params = _lm("all")
    assert lm_amm_planes(cfg, rt.amm, params) is not None


def test_apply_to_cells_are_distinct():
    """mlp / attn / all route different matmul families: all three logits
    differ pairwise, and each stays finite."""
    toks = jnp.asarray(RNG.integers(0, 512, (2, 10)), jnp.int32)
    outs = {}
    for ap in ("mlp", "attn", "all"):
        cfg, rt, params = _lm(ap)
        logits, _, _ = lm_apply(params, cfg, rt, toks, rng=jax.random.key(2))
        outs[ap] = np.asarray(logits)
        assert np.isfinite(outs[ap]).all()
    assert not np.array_equal(outs["mlp"], outs["attn"])
    assert not np.array_equal(outs["mlp"], outs["all"])
    assert not np.array_equal(outs["attn"], outs["all"])


@pytest.mark.parametrize("apply_to", ["attn", "all"])
def test_decode_matches_prefill_under_attn_routing(apply_to):
    """Cache parity: token-by-token decode through the approximate
    attention datapath reproduces the parallel forward.

    Not bitwise — decode quantizes its products over the whole cache
    slice while the chunked prefill quantizes per KV block (different
    dynamic-scale granularity, docs/attention.md) and the cache itself is
    bf16 — but it must stay within the same tolerance the exact path's
    incremental-vs-parallel test uses."""
    cfg, rt, params = _lm(apply_to)
    b, s = 2, 10
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32)
    full, _, _ = lm_apply(params, cfg, rt, toks, mode="train")
    caches = init_cache(cfg, b, 16)
    outs = []
    for t in range(s):
        lg, _, caches = lm_apply(params, cfg, rt, toks[:, t:t + 1],
                                 mode="decode", caches=caches,
                                 pos=jnp.int32(t))
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(inc - full))) < 1e-2


def test_train_step_grads_under_attn_routing():
    """STE keeps the loss differentiable with attention approximated."""
    from repro.models import lm_loss
    cfg, rt, params = _lm("all")
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    labels = jnp.roll(toks, -1, axis=-1)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, rt, toks, labels,
                          rng=jax.random.key(3))[0])(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


def test_encdec_cross_attention_routed(monkeypatch):
    """Whisper-family cross-attention is part of the apply_to contract:
    under "all" every attention() invocation — decoder self-attention
    AND both cross-attention sites — must receive the amm runtime."""
    import repro.models.transformer as tr
    seen = []
    orig = tr.attention

    def spy(*args, **kw):
        seen.append(kw.get("amm"))
        return orig(*args, **kw)

    monkeypatch.setattr(tr, "attention", spy)
    cfg = reduced(get_arch("whisper-base"))
    cfg = dataclasses.replace(cfg, amm=AmmConfig(mode="bitexact", mul="bbm0",
                                                 wl=16, param=13,
                                                 apply_to="all"))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    enc = jnp.ones((2, cfg.encoder_len, cfg.d_model), jnp.float32) * 0.01
    logits, _, _ = lm_apply(params, cfg, rt, toks, rng=jax.random.key(2),
                            encoder_embeds=enc)
    assert seen and all(a is not None for a in seen)
    assert np.isfinite(np.asarray(logits)).all()


# ------------------------------------------------ int-code KV cache oracle
def _code_cache(k, v, wl, *, block, pos=0, s_buf=None):
    """Code-cache leaves for one layer, written in one shot at ``pos``.

    ``s_buf`` sizes the cache buffer (default: exactly the written rows);
    a larger buffer leaves unwritten blocks at the 0.0 sentinel."""
    b, s, kvh, d = k.shape
    s_buf = s_buf or s
    nb = s_buf // block
    dt = code_dtype(wl)
    kc = jnp.zeros((b, s_buf, kvh, d), dt)
    vc = jnp.zeros((b, s_buf, kvh, v.shape[-1]), dt)
    ks = jnp.zeros((b, nb, kvh), jnp.float32)
    vs = jnp.zeros((b, nb, kvh), jnp.float32)
    kc, ks = code_cache_update(kc, ks, k, pos, wl=wl)
    vc, vs = code_cache_update(vc, vs, v, pos, wl=wl)
    return {"k_codes": kc, "k_scale": ks, "v_codes": vc, "v_scale": vs}


@pytest.mark.parametrize("mul,wl,vbl", SWEEP)
def test_decode_attention_codes_matches_codes_oracle(mul, wl, vbl):
    """The codes-in datapath == the scalar closed-form codes oracle, with
    multi-block scales, ragged per-slot kv_len (written-but-dead tails)
    and envelope-edge rows in both K and V."""
    rng = np.random.default_rng(17)
    b, s, kvh, d = 2, 16, 2, 8
    q = jnp.asarray(rng.standard_normal((b, 1, 4, d)), jnp.float32)
    k = rng.standard_normal((b, s, kvh, d))
    v = rng.standard_normal((b, s, kvh, d))
    k[0, 3] = np.abs(k).max() * 100.0      # pins its block's scale high
    v[1, 5] = -np.abs(v).max() * 100.0
    cache = _code_cache(jnp.asarray(k, jnp.float32),
                        jnp.asarray(v, jnp.float32), wl, block=4)
    kv_len = jnp.asarray([7, 12], jnp.int32)
    got = decode_attention_codes(q, cache, kv_len, amm=_rt(mul, wl, vbl))
    ref = amm_decode_attention_codes_ref(q, cache, kv_len,
                                         MulSpec(mul, wl, vbl))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("mul,wl,vbl", SWEEP)
def test_code_decode_degenerate_equals_requantize_path(mul, wl, vbl):
    """In the degenerate geometry — one scale block covering the whole
    slice, a single one-shot write, kv_len == written extent — the frozen
    write-time scale is bit-identical to the scale the requantize-per-call
    path derives per (slot, kv-head), so the two decodes agree bitwise.
    (The requantize reference runs ste=False: ``exact + (approx - exact)``
    is not bitwise ``approx`` in f32, and the code path never forms an
    exact product at all.)"""
    rng = np.random.default_rng(19)
    b, s, kvh, d = 2, 16, 2, 8
    q = jnp.asarray(rng.standard_normal((b, 1, 4, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, d)), jnp.float32)
    cache = _code_cache(k, v, wl, block=s)
    got = decode_attention_codes(q, cache, s, amm=_rt(mul, wl, vbl))
    ref = amm_decode_attention_ref(q, k, v, s, MulSpec(mul, wl, vbl),
                                   ste=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_frozen_codes_immune_to_later_arrivals():
    """The scale-drift regression pin.  Under the old whole-slice
    requantize, any write into the cache buffer — even past ``kv_len`` —
    moved the dynamic scale and with it every already-served token's
    bits.  Frozen codes make token t's contribution depend only on state
    at its own write: (a) appending envelope-edge rows after position n
    leaves the kv_len=n decode bitwise unchanged, (b) even a late write
    *into a live block* quantizes against the block's frozen first-touch
    scale instead of re-gridding its neighbours, and (c) the requantize
    path demonstrably drifts on the same scenario."""
    mul, wl, vbl = "bbm0", 8, 5
    rt = _rt(mul, wl, vbl)
    rng = np.random.default_rng(23)
    b, s, kvh, d, n = 2, 16, 2, 8, 8
    q = jnp.asarray(rng.standard_normal((b, 1, 4, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, n, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, n, kvh, d)), jnp.float32)
    cache = _code_cache(k, v, wl, block=4, s_buf=s)
    before = np.asarray(decode_attention_codes(q, cache, n, amm=rt))

    # (a) envelope-edge arrivals at positions >= n
    edge = jnp.full((b, 4, kvh, d), 100.0, jnp.float32)
    kc, ks = code_cache_update(cache["k_codes"], cache["k_scale"], edge, n,
                               wl=wl)
    vc, vs = code_cache_update(cache["v_codes"], cache["v_scale"], edge, n,
                               wl=wl)
    grown = {"k_codes": kc, "k_scale": ks, "v_codes": vc, "v_scale": vs}
    after = np.asarray(decode_attention_codes(q, grown, n, amm=rt))
    np.testing.assert_array_equal(before, after)

    # (b) a late write into a live block cannot re-grid its neighbours:
    # rows 0..5 freeze block 1's scale; an edge row at position 6 clips
    # against it, and rows 0..5 keep their exact codes
    part = _code_cache(k[:, :6], v[:, :6], wl, block=4, s_buf=s)
    old_rows = np.asarray(part["k_codes"])[:, :6].copy()
    kc2, ks2 = code_cache_update(part["k_codes"], part["k_scale"],
                                 edge[:, :1], 6, wl=wl)
    np.testing.assert_array_equal(np.asarray(kc2)[:, :6], old_rows)
    np.testing.assert_array_equal(np.asarray(ks2), np.asarray(part["k_scale"]))

    # (c) the documented drift this replaces: the requantize-per-call path
    # rescales the whole buffer, so the same dead-tail write changes the
    # served bits
    kf = np.zeros((b, s, kvh, d), np.float32)
    vf = np.zeros((b, s, kvh, d), np.float32)
    kf[:, :n], vf[:, :n] = np.asarray(k), np.asarray(v)
    ref_before = np.asarray(decode_attention(
        q, jnp.asarray(kf), jnp.asarray(vf), n, amm=rt, amm_ste=False))
    kf[:, n:n + 4] = 100.0
    vf[:, n:n + 4] = 100.0
    ref_after = np.asarray(decode_attention(
        q, jnp.asarray(kf), jnp.asarray(vf), n, amm=rt, amm_ste=False))
    assert not np.array_equal(ref_before, ref_after), \
        "whole-slice requantize no longer drifts; update the docs"


def test_code_cache_roundtrip_and_sentinel():
    """Dequantize inverts quantize to within one code step; untouched
    blocks keep the 0.0 never-written sentinel."""
    wl = 8
    rng = np.random.default_rng(31)
    k = jnp.asarray(rng.standard_normal((1, 8, 2, 4)), jnp.float32)
    nb = 8 // 4
    kc = jnp.zeros((1, 16, 2, 4), code_dtype(wl))
    ks = jnp.zeros((1, 4, 2), jnp.float32)
    kc, ks = code_cache_update(kc, ks, k, 0, wl=wl)
    assert (np.asarray(ks)[:, :nb] > 0).all()
    assert (np.asarray(ks)[:, nb:] == 0).all()          # sentinel intact
    deq = np.asarray(code_cache_dequant(kc, ks, kv_len=8))
    err = np.abs(deq[:, :8] - np.asarray(k))
    step = np.asarray(ks)[:, :nb].max()
    assert err.max() <= 0.5 * step + 1e-7
    assert (deq[:, 8:] == 0).all()


def test_decode_attention_codes_rejects_inactive_amm():
    q = jnp.zeros((1, 1, 2, 4), jnp.float32)
    cache = _code_cache(jnp.zeros((1, 8, 1, 4)), jnp.zeros((1, 8, 1, 4)),
                        8, block=4)
    with pytest.raises(ValueError, match="lowering"):
        decode_attention_codes(q, cache, 4, amm=None)
    with pytest.raises(ValueError, match="lowering"):
        decode_attention_codes(q, cache, 4, amm=_rt("bbm0", 8, 5, "mlp"))


def test_gqa_lm_decode_with_code_cache_tracks_float_cache():
    """Full-model GQA decode on the int-code cache: the logits stay close
    to the float-cache decode (the gap is bounded quantization error, not
    drift) and the cache leaves hold frozen int codes."""
    cfg = reduced(get_arch("qwen2-0.5b"))
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode="bitexact", mul="bbm0", wl=8, param=5,
                           apply_to="attn"))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    ccache = init_code_cache(cfg, 2, 16, wl=8)
    fcache = init_cache(cfg, 2, 16)
    snap = None
    for t in range(6):
        lc, _, ccache = lm_apply(params, cfg, rt, toks[:, t:t + 1],
                                 mode="decode", caches=ccache,
                                 pos=jnp.int32(t))
        lf, _, fcache = lm_apply(params, cfg, rt, toks[:, t:t + 1],
                                 mode="decode", caches=fcache,
                                 pos=jnp.int32(t))
        assert float(jnp.max(jnp.abs(lc - lf))) < 0.5
        if t == 2:
            snap = np.asarray(ccache["k_codes"])[:, :, :3].copy()
    assert ccache["k_codes"].dtype == jnp.int8
    # frozen-at-write at the full-model level: rows written by step 2
    # are bitwise untouched by steps 3..5
    np.testing.assert_array_equal(
        np.asarray(ccache["k_codes"])[:, :, :3], snap)


def test_mla_lm_decode_with_code_latent_cache():
    """MLA (deepseek) serves from an int-code latent cache: decode runs,
    logits stay finite and near the float-latent decode, and latent codes
    freeze at write (later steps never rewrite earlier rows)."""
    cfg = reduced(get_arch("deepseek-v3-671b"))
    cfg = dataclasses.replace(
        cfg, amm=AmmConfig(mode="bitexact", mul="bbm0", wl=8, param=5,
                           apply_to="attn"))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 5)), jnp.int32)
    ccache = init_code_cache(cfg, 2, 16, wl=8)
    assert set(ccache) == {"lat_codes", "lat_scale"}
    fcache = init_cache(cfg, 2, 16)
    snap = None
    for t in range(5):
        lc, _, ccache = lm_apply(params, cfg, rt, toks[:, t:t + 1],
                                 mode="decode", caches=ccache,
                                 pos=jnp.int32(t))
        lf, _, fcache = lm_apply(params, cfg, rt, toks[:, t:t + 1],
                                 mode="decode", caches=fcache,
                                 pos=jnp.int32(t))
        assert np.isfinite(np.asarray(lc)).all()
        assert float(jnp.max(jnp.abs(lc - lf))) < 0.5
        if t == 2:
            snap = np.asarray(ccache["lat_codes"])[:, :, :3].copy()
    assert ccache["lat_codes"].dtype == jnp.int8
    np.testing.assert_array_equal(
        np.asarray(ccache["lat_codes"])[:, :, :3], snap)


def test_mla_attn_routing_finite():
    """MLA (deepseek) threads the same amm routing through its expanded
    K/V products."""
    cfg = reduced(get_arch("deepseek-v3-671b"))
    cfg = dataclasses.replace(cfg, amm=AmmConfig(mode="bitexact", mul="bbm0",
                                                 wl=16, param=13,
                                                 apply_to="all"))
    rt = ModelRuntime.build(cfg)
    params = lm_init(cfg, jax.random.key(0))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (2, 8)), jnp.int32)
    exact_cfg = dataclasses.replace(cfg, amm=AmmConfig(mode="off"))
    l_amm, _, _ = lm_apply(params, cfg, rt, toks, rng=jax.random.key(2))
    l_off, _, _ = lm_apply(params, exact_cfg, rt=ModelRuntime.build(exact_cfg),
                           tokens=toks, rng=jax.random.key(2))
    assert np.isfinite(np.asarray(l_amm)).all()
    assert not np.array_equal(np.asarray(l_amm), np.asarray(l_off))
