"""Unit tests for model internals: SSD, chunked attention, MoE dispatch,
approximate-matmul layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import AmmConfig, get_arch, reduced
from repro.core.multipliers import MulSpec
from repro.kernels.ref import attention_ref
from repro.models.attention import chunked_attention, decode_attention
from repro.models.common import AmmRuntime, amm_dense
from repro.models.mamba2 import ssd_chunked, ssd_decode_step, ssd_reference
from repro.models.moe import _dispatch

RNG = np.random.default_rng(7)


# ----------------------------------------------------------------- SSD
@pytest.mark.parametrize("chunk", [8, 16, 64])
@pytest.mark.parametrize("groups", [1, 2])
def test_ssd_chunked_matches_reference(chunk, groups):
    b, l, h, p, n = 2, 64, 4, 8, 16
    x = jnp.asarray(RNG.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, l, h))) * 0.5 + 0.1,
                     jnp.float32)
    A = -jnp.asarray(np.abs(RNG.standard_normal(h)) + 0.2, jnp.float32)
    B_ = jnp.asarray(RNG.standard_normal((b, l, groups, n)), jnp.float32)
    C_ = jnp.asarray(RNG.standard_normal((b, l, groups, n)), jnp.float32)
    D = jnp.asarray(RNG.standard_normal(h), jnp.float32)
    y, _ = ssd_chunked(x, dt, A, B_, C_, D, chunk=chunk)
    y_ref = ssd_reference(x, dt, A, B_, C_, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-5, rtol=1e-4)


def test_ssd_final_state_continues_decode():
    """Chunked prefill state must seed exact decode continuation."""
    b, l, h, p, n = 1, 32, 2, 4, 8
    x = jnp.asarray(RNG.standard_normal((b, l + 1, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, l + 1, h))) * 0.3 + 0.1,
                     jnp.float32)
    A = -jnp.asarray(np.abs(RNG.standard_normal(h)) + 0.2, jnp.float32)
    B_ = jnp.asarray(RNG.standard_normal((b, l + 1, 1, n)), jnp.float32)
    C_ = jnp.asarray(RNG.standard_normal((b, l + 1, 1, n)), jnp.float32)
    D = jnp.asarray(RNG.standard_normal(h), jnp.float32)
    y_all = ssd_reference(x, dt, A, B_, C_, D)
    _, state = ssd_chunked(x[:, :l], dt[:, :l], A, B_[:, :l], C_[:, :l], D,
                           chunk=8)
    bt = jnp.repeat(B_[:, l], h, axis=1)
    ct = jnp.repeat(C_[:, l], h, axis=1)
    y_t, _ = ssd_decode_step(state, x[:, l], dt[:, l], A, bt, ct, D)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_all[:, l]),
                               atol=5e-5, rtol=1e-4)


# ------------------------------------------------------- chunked attention
@pytest.mark.parametrize("shape", [(2, 96, 4, 2, 32), (1, 130, 6, 3, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_attention_matches_naive(shape, causal):
    b, s, h, kvh, d = shape
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kvh, d)), jnp.float32)
    got = chunked_attention(q, k, v, causal=causal, bq=32, bk=32)
    groups = h // kvh
    kk = jnp.repeat(k, groups, axis=2)
    vv = jnp.repeat(v, groups, axis=2)
    ref = attention_ref(q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
                        vv.transpose(0, 2, 1, 3), causal=causal)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.transpose(0, 2, 1, 3)),
                               atol=3e-5)


def test_chunked_attention_mixed_kv_dims():
    """MLA shape: d_k != d_v."""
    b, s, h = 1, 64, 4
    q = jnp.asarray(RNG.standard_normal((b, s, h, 24)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, 24)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, 16)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, bq=16, bk=16)
    assert out.shape == (b, s, h, 16)
    assert np.isfinite(np.asarray(out)).all()


def test_decode_attention_matches_full():
    b, s, h, kvh, d = 2, 40, 4, 2, 16
    q = jnp.asarray(RNG.standard_normal((b, 1, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, kvh, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, kvh, d)), jnp.float32)
    kv_len = 17
    got = decode_attention(q, k, v, kv_len=kv_len)
    ref = chunked_attention(q, k[:, :kv_len], v[:, :kv_len], causal=False,
                            bq=8, bk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)


# ---------------------------------------------------------- MoE dispatch
@given(t=st.integers(4, 64), e=st.integers(2, 8), k=st.integers(1, 2),
       seed=st.integers(0, 100))
@settings(max_examples=60, deadline=None)
def test_prop_moe_dispatch_invariants(t, e, k, seed):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, e, t * k), jnp.int32)
    cap = max(int(1.25 * k * t / e), 1)
    slot_token, token_slot = _dispatch(ids, k, t, e, cap)
    slot_token = np.asarray(slot_token)
    token_slot = np.asarray(token_slot)
    nc = e * cap
    # every kept decision points at a slot holding its own token
    for d_idx in range(t * k):
        s_ = token_slot[d_idx]
        if s_ < nc:
            assert slot_token[s_] == d_idx // k
            assert s_ // cap == int(ids[d_idx])   # correct expert bucket
    # no expert bucket oversubscribed; pad slots hold the sentinel
    for s_ in range(nc):
        assert slot_token[s_] == t or slot_token[s_] < t


def test_moe_dropless_when_capacity_ample():
    """With capacity >= T the combine is a exact weighted expert sum."""
    from repro.models.moe import moe_apply, moe_table
    from repro.models.common import init_params
    cfg = reduced(get_arch("grok-1-314b"))
    p = init_params(moe_table(cfg), jax.random.key(0))
    x = jnp.asarray(RNG.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, aux = moe_apply(p, x, cfg, capacity_factor=float(cfg.n_experts))
    # reference: dense computation over all experts with same gating
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.sigmoid(logits)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["w_gate"])) * \
        jnp.einsum("td,edf->tef", xf, p["w_up"])
    ye = jnp.einsum("tef,efd->ted", h, p["w_down"])
    ref = jnp.zeros_like(xf)
    for j in range(cfg.top_k):
        ref = ref + gv[:, j:j + 1] * jnp.take_along_axis(
            ye, gi[:, j][:, None, None], axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=2e-5)


# ------------------------------------------------------------- amm layer
def test_amm_noise_mode_moments():
    rt = AmmRuntime.build(AmmConfig(mode="noise", mul="bbm0", wl=12, param=9))
    x = jnp.asarray(RNG.standard_normal((64, 128)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((128, 64)), jnp.float32)
    y = amm_dense(x, w, rt, key=jax.random.key(0))
    exact = x @ w
    assert y.shape == exact.shape
    # error scale: |mu| * K * s_x * s_w should dominate and be visible
    rel = float(jnp.mean(jnp.abs(y - exact)) / jnp.mean(jnp.abs(exact)))
    assert 1e-5 < rel < 0.5


def test_amm_bitexact_mode_matches_core():
    rt = AmmRuntime.build(AmmConfig(mode="bitexact", mul="bbm0", wl=8,
                                    param=5))
    x = jnp.asarray(RNG.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((16, 8)), jnp.float32)
    y = amm_dense(x, w, rt)
    assert np.isfinite(np.asarray(y)).all()
    # vbl=0 -> quantization only, still close to exact
    rt0 = AmmRuntime.build(AmmConfig(mode="bitexact", mul="bbm0", wl=12,
                                     param=0))
    y0 = amm_dense(x, w, rt0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x @ w), atol=0.05)


def test_amm_gradients_are_ste():
    """Gradients flow as if the matmul were exact (straight-through)."""
    rt = AmmRuntime.build(AmmConfig(mode="noise", mul="bbm0", wl=12, param=9))
    x = jnp.asarray(RNG.standard_normal((4, 8)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((8, 4)), jnp.float32)
    g1 = jax.grad(lambda ww: jnp.sum(amm_dense(x, ww, rt,
                                               key=jax.random.key(1))))(w)
    g2 = jax.grad(lambda ww: jnp.sum(x @ ww))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)
