"""Fault injection: the faulted datapath must still equal its oracle.

The robustness layer's load-bearing contract is that ``FaultSpec``
faults are *shared state*, not datapath-specific noise: the dot-form
lowering (``kernels.bbm_matmul``) and the scalar oracle
(``kernels.ref.amm_faulty_ref``) draw identical keyed masks over
identical representations (digit planes pre-padding, per-chunk int32
partials), so fault-injected dot-vs-oracle equality stays
``assert_array_equal`` — the repo's contract idiom — across word
lengths, VBLs, truncation kinds and fault models.  A disabled spec must
be *bit-identical* to the unfaulted datapath (python-level identity, not
just numerically close).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_array_equal

from repro.core import FaultSpec
from repro.core.faults import (apply_acc_fault, apply_plane_faults,
                               plane_fault_mask)
from repro.core.multipliers import MulSpec
from repro.kernels.bbm_matmul import bbm_matmul_dynamic
from repro.kernels.booth_rows import booth_precode
from repro.kernels.ref import amm_approx_ref, amm_faulty_ref

RNG = np.random.default_rng(17)

# both truncation kinds at every word length, the exact multiplier
# (vbl=0), and (16, 3) whose small chunk length exercises the chunked
# accumulation schedule (and therefore per-chunk fault keying) at K=70
SWEEP = [("bbm0", 8, 5), ("bbm1", 8, 7), ("bbm0", 12, 7),
         ("bbm1", 12, 11), ("bbm0", 16, 13), ("bbm1", 16, 15),
         ("bbm0", 16, 3), ("booth", 16, 0)]

# stuck-at defects and keyed transient flips, plane and accumulator
# sites, single-lane and all-lane, correction-rows-only
FAULTS = [
    FaultSpec(target="plane", model="flip", p=0.05, lane="all", seed=3),
    FaultSpec(target="plane", model="stuck1", p=0.07, lane="mag_lo",
              seed=5),
    FaultSpec(target="plane", model="stuck0", p=0.2, lane="neg",
              rows="corr", seed=9),
    FaultSpec(target="acc", model="flip", p=0.25, bit=11, seed=7),
]


def _operands(m=4, k=70, n=8):
    x = RNG.standard_normal((m, k)).astype(np.float32)
    w = RNG.standard_normal((k, n)).astype(np.float32)
    return x, w


def _kind(mul):
    return {"booth": 0, "bbm0": 0, "bbm1": 1}[mul]


@pytest.mark.parametrize("mul,wl,vbl", SWEEP)
def test_faulted_dot_equals_faulted_oracle(mul, wl, vbl):
    """Every fault model, bit-for-bit, across the spec sweep."""
    x, w = _operands()
    spec = MulSpec(mul, wl, vbl)
    v = 0 if mul == "booth" else vbl
    for fault in FAULTS:
        got = np.asarray(bbm_matmul_dynamic(x, w, wl=wl, vbl=v,
                                            kind=_kind(mul), fault=fault))
        ref = np.asarray(amm_faulty_ref(x, w, spec, fault=fault))
        assert_array_equal(got, ref)


@pytest.mark.parametrize("mul,wl,vbl", [("bbm0", 16, 13), ("bbm1", 12, 7),
                                        ("booth", 16, 0)])
def test_disabled_fault_is_bit_identical(mul, wl, vbl):
    """fault=None, a rate-0 spec, and the unfaulted entry point agree
    bitwise — the robustness hooks must cost nothing when off."""
    x, w = _operands()
    spec = MulSpec(mul, wl, vbl)
    v = 0 if mul == "booth" else vbl
    base = np.asarray(bbm_matmul_dynamic(x, w, wl=wl, vbl=v,
                                         kind=_kind(mul)))
    for fault in (None, FaultSpec(p=0.0),
                  FaultSpec(target="acc", p=0.0)):
        got = np.asarray(bbm_matmul_dynamic(x, w, wl=wl, vbl=v,
                                            kind=_kind(mul), fault=fault))
        assert_array_equal(got, base)
        assert_array_equal(np.asarray(amm_faulty_ref(x, w, spec,
                                                     fault=fault)),
                           np.asarray(amm_approx_ref(x, w, spec)))


def test_faults_actually_fault():
    """A rate-p spec must change outputs (guards the no-op regression)."""
    x, w = _operands()
    spec = MulSpec("bbm0", 16, 13)
    base = np.asarray(amm_approx_ref(x, w, spec))
    for fault in FAULTS[:2] + FAULTS[3:]:     # corr-rows at vbl=13 too
        got = np.asarray(bbm_matmul_dynamic(x, w, wl=16, vbl=13, kind=0,
                                            fault=fault))
        assert (got != base).any(), fault


def test_plane_faults_stay_in_decode_domain():
    """Whatever the fault does to the stored bits, the faulted planes
    must remain in the {0,1,2} x {0,1} domain the accumulate forms and
    ``_MOD_BRANCHES`` enumerate (the 11 select saturates to 2A)."""
    codes = jnp.asarray(RNG.integers(0, 1 << 16, (32, 8)), jnp.int32)
    mag, neg = booth_precode(codes, 16)
    for model in ("flip", "stuck0", "stuck1"):
        spec = FaultSpec(target="plane", model=model, p=0.5, lane="all",
                         seed=1)
        fm, fn = apply_plane_faults(mag, neg, spec, vbl=13)
        assert int(jnp.max(fm)) <= 2 and int(jnp.min(fm)) >= 0
        assert set(np.unique(np.asarray(fn))) <= {0, 1}


def test_corr_rows_restriction_leaves_upper_rows_clean():
    """rows="corr" confines the site to the ceil(vbl/2) truncated rows."""
    codes = jnp.asarray(RNG.integers(0, 1 << 16, (64, 4)), jnp.int32)
    mag, neg = booth_precode(codes, 16)
    vbl = 13
    spec = FaultSpec(target="plane", model="flip", p=0.9, lane="all",
                     rows="corr", seed=2)
    fm, fn = apply_plane_faults(mag, neg, spec, vbl=vbl)
    n_corr = (vbl + 1) // 2
    assert_array_equal(np.asarray(fm)[n_corr:], np.asarray(mag)[n_corr:])
    assert_array_equal(np.asarray(fn)[n_corr:], np.asarray(neg)[n_corr:])
    assert (np.asarray(fm)[:n_corr] != np.asarray(mag)[:n_corr]).any()


def test_masks_are_keyed_and_deterministic():
    """Same spec -> same mask; different seed/lane/chunk -> different."""
    spec = FaultSpec(target="plane", model="flip", p=0.3, seed=4)
    m1 = np.asarray(plane_fault_mask(spec, (8, 16, 4), 0))
    m2 = np.asarray(plane_fault_mask(spec, (8, 16, 4), 0))
    assert_array_equal(m1, m2)
    other = np.asarray(plane_fault_mask(
        dataclasses.replace(spec, seed=5), (8, 16, 4), 0))
    assert (m1 != other).any()
    assert (m1 != np.asarray(plane_fault_mask(spec, (8, 16, 4), 1))).any()
    acc = jnp.zeros((16, 16), jnp.int32)
    a0 = np.asarray(apply_acc_fault(
        acc, FaultSpec(target="acc", p=0.4, bit=5, seed=4), 0))
    a1 = np.asarray(apply_acc_fault(
        acc, FaultSpec(target="acc", p=0.4, bit=5, seed=4), 1))
    assert (a0 != a1).any()               # chunk index folds into the key
    assert set(np.unique(a0)) <= {0, 1 << 5}


def test_acc_fault_is_an_xor_at_the_named_bit():
    acc = jnp.asarray(RNG.integers(-1000, 1000, (8, 8)), jnp.int32)
    spec = FaultSpec(target="acc", model="flip", p=1.0, bit=7, seed=0)
    out = np.asarray(apply_acc_fault(acc, spec, 0))
    assert_array_equal(out, np.asarray(acc) ^ (1 << 7))


def test_faultspec_validation():
    for bad in [dict(target="dram"), dict(model="stuck2"),
                dict(lane="carry"), dict(rows="even"), dict(p=1.5),
                dict(p=-0.1), dict(bit=31), dict(bit=-1)]:
        with pytest.raises(ValueError):
            FaultSpec(**bad)
    assert not FaultSpec().enabled
    assert FaultSpec(p=0.1).enabled
