"""Per-architecture smoke tests (reduced configs, CPU): forward/train/decode
shape + finiteness, and incremental-vs-parallel consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch, reduced
from repro.models import (ModelRuntime, init_cache, lm_apply, lm_init,
                          lm_loss)


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCH_NAMES:
        cfg = reduced(get_arch(name))
        rt = ModelRuntime.build(cfg)
        params = lm_init(cfg, jax.random.key(0))
        out[name] = (cfg, rt, params)
    return out


def _enc(cfg, b):
    if not cfg.is_encoder_decoder:
        return None
    return jnp.ones((b, cfg.encoder_len, cfg.d_model), jnp.float32) * 0.01


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_finite(built, name):
    cfg, rt, params = built[name]
    b, s = 2, 32
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab)
    logits, aux, _ = lm_apply(params, cfg, rt, toks, mode="train",
                              encoder_embeds=_enc(cfg, b))
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_grads(built, name):
    """One loss+grad evaluation: finite loss, finite nonzero grads."""
    cfg, rt, params = built[name]
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, axis=-1)

    def loss_fn(p):
        total, _ = lm_loss(p, cfg, rt, toks, labels,
                           rng=jax.random.key(3),
                           encoder_embeds=_enc(cfg, b))
        return total

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step(built, name):
    cfg, rt, params = built[name]
    b = 2
    caches = init_cache(cfg, b, 16)
    tok = jax.random.randint(jax.random.key(4), (b, 1), 0, cfg.vocab)
    logits, _, newc = lm_apply(params, cfg, rt, tok, mode="decode",
                               caches=caches, pos=jnp.int32(3),
                               encoder_embeds=_enc(cfg, b))
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(newc) == jax.tree.structure(caches)


@pytest.mark.parametrize("name", ["llama3.2-3b", "qwen2-0.5b", "yi-34b",
                                  "chameleon-34b", "mamba2-370m",
                                  "zamba2-2.7b", "whisper-base",
                                  "deepseek-v3-671b", "grok-1-314b",
                                  "qwen1.5-110b"])
def test_incremental_matches_parallel(built, name):
    """Token-by-token decode reproduces the parallel forward.

    MoE archs get a looser bound: train-time capacity dropping is batch-
    composition dependent (decode runs dropless), which is inherent to
    dropping MoEs, not a cache bug.
    """
    cfg, rt, params = built[name]
    b, s = 2, 10
    toks = jax.random.randint(jax.random.key(5), (b, s), 0, cfg.vocab)
    enc = _enc(cfg, b)
    full, _, _ = lm_apply(params, cfg, rt, toks, mode="train",
                          encoder_embeds=enc)
    caches = init_cache(cfg, b, 16)
    outs = []
    for t in range(s):
        lg, _, caches = lm_apply(params, cfg, rt, toks[:, t:t + 1],
                                 mode="decode", caches=caches,
                                 pos=jnp.int32(t), encoder_embeds=enc)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    tol = 0.1 if cfg.family == "moe" else 1e-2
    assert float(jnp.max(jnp.abs(inc - full))) < tol


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the assigned hyperparameters."""
    expect = {
        "deepseek-v3-671b": (61, 7168, 128, 129280),
        "grok-1-314b": (64, 6144, 48, 131072),
        "mamba2-370m": (48, 1024, 0, 50280),
        "qwen1.5-110b": (80, 8192, 64, 152064),
        "qwen2-0.5b": (24, 896, 14, 151936),
        "llama3.2-3b": (28, 3072, 24, 128256),
        "yi-34b": (60, 7168, 56, 64000),
        "whisper-base": (6, 512, 8, 51865),
        "chameleon-34b": (48, 8192, 64, 65536),
        "zamba2-2.7b": (54, 2560, 32, 32000),
    }
    for name, (nl, dm, nh, v) in expect.items():
        c = get_arch(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (nl, dm, nh, v), name
    assert get_arch("deepseek-v3-671b").n_experts == 256
    assert get_arch("deepseek-v3-671b").top_k == 8
    assert get_arch("grok-1-314b").n_experts == 8
    assert get_arch("mamba2-370m").ssm_state == 128
    assert get_arch("zamba2-2.7b").ssm_state == 64
