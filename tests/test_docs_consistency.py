"""Docs-consistency gate: no reference to a nonexistent repo file.

The EXPERIMENTS.md class of rot: a docstring or doc page cites a repo
file that was never committed (or was later renamed) and every reader
after that chases a ghost.  This test scans the python sources and the
markdown docs for ``*.md`` and ``*.py`` path references and fails when a
referenced file does not exist — relative to the repo root, to the
referencing file's own directory, or to ``docs/``.

Scope is deliberately the *maintained* surfaces: ``src``, ``docs``,
``tests``, ``benchmarks``, ``examples`` plus the top-level README and
ROADMAP.  CHANGES.md (an append-only history), ISSUE.md and the
retrieval artifacts (PAPER/PAPERS/SNIPPETS) are historical records, not
live documentation, and may legitimately name files that no longer
exist.
"""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

SCAN_DIRS = ["src", "docs", "tests", "benchmarks", "examples"]
SCAN_FILES = ["README.md", "ROADMAP.md"]

# path-ish tokens ending in .md or .py; the leading charset excludes
# sentence punctuation so prose like "foo.md." strips cleanly
_REF = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:md|py)\b")

# names that are not repo-file references
_IGNORE = {
    "conftest.py",            # pytest convention, resolved by pytest itself
    "setup.py",               # generic packaging prose
}


def _scan_targets():
    me = Path(__file__).resolve()
    for d in SCAN_DIRS:
        for p in sorted((ROOT / d).rglob("*")):
            if (p.suffix in (".py", ".md") and p.is_file()
                    and p.resolve() != me):
                yield p
    for f in SCAN_FILES:
        p = ROOT / f
        if p.exists():
            yield p


def _resolves(ref: str, source: Path) -> bool:
    candidates = [ROOT / ref, source.parent / ref, ROOT / "docs" / ref,
                  # src-layout and package-relative spellings:
                  # "repro/launch/serve.py", "kernels/bbm_matmul.py"
                  ROOT / "src" / ref, ROOT / "src" / "repro" / ref]
    return any(c.is_file() for c in candidates)


def test_no_references_to_missing_repo_files():
    missing = []
    for path in _scan_targets():
        text = path.read_text(encoding="utf-8")
        for m in _REF.finditer(text):
            ref = m.group(0).rstrip(".")
            name = ref.rsplit("/", 1)[-1]
            if name in _IGNORE:
                continue
            if not _resolves(ref, path):
                line = text.count("\n", 0, m.start()) + 1
                missing.append(f"{path.relative_to(ROOT)}:{line}: {ref}")
    assert not missing, (
        "references to nonexistent repo files (the EXPERIMENTS.md class "
        "of rot):\n  " + "\n  ".join(sorted(set(missing))))
